//! Typed, validated training parameters — the data half of the [`Learner`]
//! façade (`crate::gbm::learner`).
//!
//! The five formerly stringly-typed booster fields are real enums here —
//! [`ObjectiveKind`], [`MetricKind`], [`GrowPolicy`], [`AllReduce`],
//! [`MonotoneConstraints`] — each implementing `FromStr`/`Display` so CLI
//! and config text round-trips losslessly, and [`LearnerParams::validate`]
//! performs the full cross-field check up front (returning *every*
//! violation, not just the first) so invalid configurations can no longer
//! fail mid-training.
//!
//! [`Learner`]: crate::gbm::learner::Learner

use std::fmt;
use std::str::FromStr;

use anyhow::{Context as _, Result};

use crate::coordinator::CoordinatorParams;
use crate::gbm::registry::{MetricRegistry, ObjectiveRegistry};
use crate::util::Config;

// The growth-policy and all-reduce selectors already exist as enums deeper
// in the stack; the learner API re-exports them under their XGBoost-facing
// names so the whole typed parameter surface lives in one module.
pub use crate::comm::AllReduceAlgo as AllReduce;
pub use crate::comm::WirePayload;
pub use crate::tree::GrowthPolicy as GrowPolicy;

/// Training objective selector (XGBoost-style names).
///
/// Unknown names parse into [`ObjectiveKind::Custom`]; whether such a name
/// actually resolves is checked by [`LearnerParams::validate`] against the
/// [`ObjectiveRegistry`], so user-registered objectives are first-class in
/// config files and on the CLI.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// `reg:squarederror` (alias `reg:linear` accepted on parse).
    #[default]
    SquaredError,
    /// `binary:logistic`.
    BinaryLogistic,
    /// `multi:softmax` — argmax class output; requires `num_class >= 2`.
    MultiSoftmax,
    /// `multi:softprob` — flattened probability matrix output.
    MultiSoftprob,
    /// `rank:pairwise`.
    RankPairwise,
    /// `reg:quantile` — pinball loss at [`LearnerParams::quantile_alpha`].
    QuantileReg,
    /// `reg:tweedie` — compound-Poisson deviance at
    /// [`LearnerParams::tweedie_variance_power`] ∈ (1, 2).
    Tweedie,
    /// `survival:aft` — accelerated failure time over `(lower, upper)`
    /// interval labels ([`LearnerParams::aft_distribution`] /
    /// [`LearnerParams::aft_sigma`]).
    SurvivalAft,
    /// A name resolved through the [`ObjectiveRegistry`] at build time.
    Custom(String),
}

impl ObjectiveKind {
    /// Canonical names of the built-in objectives.
    pub const BUILTIN_NAMES: [&'static str; 8] = [
        "reg:squarederror",
        "binary:logistic",
        "multi:softmax",
        "multi:softprob",
        "rank:pairwise",
        "reg:quantile",
        "reg:tweedie",
        "survival:aft",
    ];

    /// The canonical name (what `Display` prints and model files store).
    pub fn name(&self) -> &str {
        match self {
            ObjectiveKind::SquaredError => "reg:squarederror",
            ObjectiveKind::BinaryLogistic => "binary:logistic",
            ObjectiveKind::MultiSoftmax => "multi:softmax",
            ObjectiveKind::MultiSoftprob => "multi:softprob",
            ObjectiveKind::RankPairwise => "rank:pairwise",
            ObjectiveKind::QuantileReg => "reg:quantile",
            ObjectiveKind::Tweedie => "reg:tweedie",
            ObjectiveKind::SurvivalAft => "survival:aft",
            ObjectiveKind::Custom(name) => name,
        }
    }

    /// Does this objective train `num_class` tree groups per round?
    pub fn is_multiclass(&self) -> bool {
        matches!(self, ObjectiveKind::MultiSoftmax | ObjectiveKind::MultiSoftprob)
    }
}

impl fmt::Display for ObjectiveKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ObjectiveKind {
    type Err = std::convert::Infallible;

    /// Never fails: unknown names become [`ObjectiveKind::Custom`] and are
    /// rejected (with the valid-name list) by [`LearnerParams::validate`].
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "reg:squarederror" | "reg:linear" => ObjectiveKind::SquaredError,
            "binary:logistic" => ObjectiveKind::BinaryLogistic,
            "multi:softmax" => ObjectiveKind::MultiSoftmax,
            "multi:softprob" => ObjectiveKind::MultiSoftprob,
            "rank:pairwise" => ObjectiveKind::RankPairwise,
            "reg:quantile" => ObjectiveKind::QuantileReg,
            "reg:tweedie" => ObjectiveKind::Tweedie,
            "survival:aft" => ObjectiveKind::SurvivalAft,
            other => ObjectiveKind::Custom(other.to_string()),
        })
    }
}

/// Evaluation metric selector.
///
/// Like [`ObjectiveKind`], unknown names parse into [`MetricKind::Custom`]
/// and are validated against the [`MetricRegistry`] at build time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MetricKind {
    Rmse,
    Mae,
    LogLoss,
    /// `accuracy` (alias `acc` accepted on parse).
    Accuracy,
    Error,
    Auc,
    MError,
    Ndcg,
    /// A name resolved through the [`MetricRegistry`] at build time.
    Custom(String),
}

impl MetricKind {
    /// Canonical names of the built-in metrics. The last three are
    /// parametrised — they also resolve in `name@param` form (e.g.
    /// `pinball@0.9`, `tweedie-nloglik@1.3`, `aft-nloglik@logistic,1.5`)
    /// and are represented as [`MetricKind::Custom`] so the parameter
    /// survives the round-trip.
    pub const BUILTIN_NAMES: [&'static str; 11] = [
        "rmse",
        "mae",
        "logloss",
        "accuracy",
        "error",
        "auc",
        "merror",
        "ndcg",
        "pinball",
        "tweedie-nloglik",
        "aft-nloglik",
    ];

    /// The canonical name (what `Display` prints).
    pub fn name(&self) -> &str {
        match self {
            MetricKind::Rmse => "rmse",
            MetricKind::Mae => "mae",
            MetricKind::LogLoss => "logloss",
            MetricKind::Accuracy => "accuracy",
            MetricKind::Error => "error",
            MetricKind::Auc => "auc",
            MetricKind::MError => "merror",
            MetricKind::Ndcg => "ndcg",
            MetricKind::Custom(name) => name,
        }
    }
}

impl fmt::Display for MetricKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for MetricKind {
    type Err = std::convert::Infallible;

    /// Never fails: unknown names become [`MetricKind::Custom`] and are
    /// rejected (with the valid-name list) by [`LearnerParams::validate`].
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "rmse" => MetricKind::Rmse,
            "mae" => MetricKind::Mae,
            "logloss" => MetricKind::LogLoss,
            "accuracy" | "acc" => MetricKind::Accuracy,
            "error" => MetricKind::Error,
            "auc" => MetricKind::Auc,
            "merror" => MetricKind::MError,
            "ndcg" => MetricKind::Ndcg,
            other => MetricKind::Custom(other.to_string()),
        })
    }
}

/// Error distribution of the accelerated-failure-time objective
/// (`survival:aft`): the model is `ln t = margin + σ·ε` with `ε` drawn
/// from this distribution.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum AftDistribution {
    #[default]
    Normal,
    Logistic,
}

impl AftDistribution {
    pub fn name(&self) -> &'static str {
        match self {
            AftDistribution::Normal => "normal",
            AftDistribution::Logistic => "logistic",
        }
    }
}

impl fmt::Display for AftDistribution {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for AftDistribution {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "normal" => Ok(AftDistribution::Normal),
            "logistic" => Ok(AftDistribution::Logistic),
            other => Err(format!(
                "unknown aft_distribution {other:?}; valid: normal, logistic"
            )),
        }
    }
}

/// The objective-shaping parameters an [`ObjectiveRegistry`] factory needs
/// beyond the objective's name — carried separately from [`LearnerParams`]
/// so model loading and serving can construct objectives without a full
/// learner configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectiveParams {
    pub num_class: usize,
    /// Target quantile of `reg:quantile`, in (0, 1).
    pub quantile_alpha: f64,
    /// Tweedie variance power ρ of `reg:tweedie`, in (1, 2).
    pub tweedie_variance_power: f64,
    /// Error distribution of `survival:aft`.
    pub aft_distribution: AftDistribution,
    /// Scale σ of `survival:aft`, > 0.
    pub aft_sigma: f64,
}

impl Default for ObjectiveParams {
    fn default() -> Self {
        ObjectiveParams {
            num_class: 1,
            quantile_alpha: 0.5,
            tweedie_variance_power: 1.5,
            aft_distribution: AftDistribution::Normal,
            aft_sigma: 1.0,
        }
    }
}

/// Per-feature monotonicity constraints (+1 increasing, 0 free, −1
/// decreasing). A list shorter than the feature count implies 0 for the
/// remaining features; a *longer* list is rejected at build/train time.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MonotoneConstraints(Vec<i8>);

impl MonotoneConstraints {
    /// No constraints (the default).
    pub fn none() -> Self {
        MonotoneConstraints(Vec::new())
    }

    /// Build from explicit per-feature signs, validating each is −1/0/+1.
    pub fn new(signs: Vec<i8>) -> Result<Self, String> {
        if let Some(bad) = signs.iter().find(|s| !(-1..=1).contains(*s)) {
            return Err(format!("monotone constraint must be -1, 0 or 1, got {bad}"));
        }
        Ok(MonotoneConstraints(signs))
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn as_slice(&self) -> &[i8] {
        &self.0
    }

    /// Error message if the list is longer than the dataset is wide.
    pub fn check_n_features(&self, n_features: usize) -> Result<(), String> {
        if self.0.len() > n_features {
            Err(format!(
                "monotone_constraints has {} entries but the data has only {} features",
                self.0.len(),
                n_features
            ))
        } else {
            Ok(())
        }
    }
}

impl FromStr for MonotoneConstraints {
    type Err = String;

    /// Parse `"1,0,-1"` or `"(1,0,-1)"`; empty means unconstrained.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim().trim_start_matches('(').trim_end_matches(')');
        if t.is_empty() {
            return Ok(MonotoneConstraints::none());
        }
        let signs = t
            .split(',')
            .map(|tok| {
                let v = tok
                    .trim()
                    .parse::<i32>()
                    .map_err(|_| format!("monotone_constraints: cannot parse {tok:?} as integer"))?;
                // validate before narrowing so e.g. 256 can't wrap into range
                if !(-1..=1).contains(&v) {
                    return Err(format!("monotone constraint must be -1, 0 or 1, got {v}"));
                }
                Ok(v as i8)
            })
            .collect::<Result<Vec<i8>, String>>()?;
        Ok(MonotoneConstraints(signs))
    }
}

impl fmt::Display for MonotoneConstraints {
    /// Canonical form `"(1,0,-1)"`; empty constraints print as `""`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0.is_empty() {
            return Ok(());
        }
        let body: Vec<String> = self.0.iter().map(|s| s.to_string()).collect();
        write!(f, "({})", body.join(","))
    }
}

/// All invalid-configuration findings from [`LearnerParams::validate`],
/// reported together so a config can be fixed in one pass.
#[derive(Debug, Clone)]
pub struct ValidationErrors(pub Vec<String>);

impl fmt::Display for ValidationErrors {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid learner configuration ({} problems)", self.0.len())?;
        for e in &self.0 {
            write!(f, "\n  - {e}")?;
        }
        Ok(())
    }
}

impl std::error::Error for ValidationErrors {}

/// Typed booster hyperparameters (XGBoost-style names).
///
/// Construct via [`LearnerBuilder`](crate::gbm::learner::LearnerBuilder)
/// (which validates), [`LearnerParams::from_config`], or directly as a
/// struct literal when you know the configuration is sound — training
/// still runs [`LearnerParams::validate`] before touching data.
#[derive(Debug, Clone)]
pub struct LearnerParams {
    pub objective: ObjectiveKind,
    pub num_class: usize,
    pub num_rounds: usize,
    pub eta: f64,
    pub max_depth: usize,
    pub max_leaves: usize,
    pub max_bins: usize,
    pub lambda: f64,
    pub gamma: f64,
    pub alpha: f64,
    pub min_child_weight: f64,
    /// Growth strategy (§2.3).
    pub grow_policy: GrowPolicy,
    /// Simulated device count (the paper's GPUs).
    pub n_devices: usize,
    /// Bit-packed shard storage (§2.2).
    pub compress: bool,
    /// Histogram all-reduce algorithm.
    pub allreduce: AllReduce,
    /// Evaluation metric; `None` = the objective's default.
    pub eval_metric: Option<MetricKind>,
    /// Evaluate every k rounds (0 = only at the end).
    pub eval_every: usize,
    /// Stop if the validation metric hasn't improved in this many
    /// evaluations (0 = never).
    pub early_stopping_rounds: usize,
    /// Row subsampling rate per tree (1.0 = off).
    pub subsample: f64,
    /// Column sampling rate per tree (1.0 = off).
    pub colsample_bytree: f64,
    /// Per-feature monotone constraints; empty = none.
    pub monotone_constraints: MonotoneConstraints,
    /// Seed for subsampling / column sampling.
    pub seed: u64,
    /// Print eval lines to stderr.
    pub verbose: bool,
    /// Worker threads for the real parallel engine (`crate::exec`):
    /// device shards run concurrently and the hot loops (histograms,
    /// repartitioning, sketching, gradients, prediction) are
    /// chunk-parallel. `0` = all cores, `1` = serial. Trees, predictions
    /// and metrics are **bit-identical** for every value — the knob only
    /// changes wall-clock.
    pub threads: usize,
    /// Rows per batch for the streaming ingestion pipeline
    /// (`Learner::train_from_source`; CLI `--stream`): bounds the
    /// transient float-buffer footprint at O(`batch_rows × n_cols`).
    /// Models are **bit-identical** for every value — the knob only
    /// trades peak memory against per-batch overhead.
    pub batch_rows: usize,
    /// External-memory budget (CLI `--max-resident-pages`): maximum
    /// bit-packed pages each device shard keeps resident. `0` (default)
    /// = fully resident; `> 0` spills sealed pages to a per-shard temp
    /// file and runs histogram rounds page-at-a-time with async
    /// prefetch, bounding peak resident compressed bytes per shard by
    /// `max_resident_pages × page_bytes`. Requires `compress`. Models
    /// are **bit-identical** for every budget and page size.
    pub max_resident_pages: usize,
    /// Rows per sealed page when spilling (CLI `--page-rows`); ignored
    /// while fully resident. Bit-identity holds for every value.
    pub page_rows: usize,
    /// This process's rank in a distributed run (CLI `--dist-rank`).
    /// Ignored while [`dist_peers`](Self::dist_peers) is empty.
    pub dist_rank: usize,
    /// `host:port` listen addresses of every rank, in rank order (CLI
    /// `--dist-peers`, comma-separated). Empty (the default) = train in
    /// one process with simulated devices. Non-empty engages the real
    /// TCP ring all-reduce ([`crate::comm::wire`]): each listed process
    /// builds only its own rank's device histograms and merges over the
    /// wire, producing trees **bit-identical** to a single-process run
    /// with `n_devices == dist_peers.len()`. Requires `n_devices ==
    /// dist_peers.len()`, `dist_rank < dist_peers.len()` and
    /// `allreduce = ring`.
    pub dist_peers: Vec<String>,
    /// Wire encoding for distributed histogram chunks (CLI
    /// `--dist-payload`): `quant` (default) packs through the
    /// `compress/` symbol machinery losslessly, `raw` ships plain f64
    /// bytes. Both are bit-identical; `quant` cuts wire bytes.
    pub dist_payload: WirePayload,
    /// Target quantile α of `reg:quantile` (CLI `--quantile-alpha`), in
    /// (0, 1). The subgradient-at-zero convention: residual `y − m > 0`
    /// strictly takes gradient −α, everything else (including the kink at
    /// 0) takes 1 − α.
    pub quantile_alpha: f64,
    /// Tweedie variance power ρ of `reg:tweedie` (CLI
    /// `--tweedie-variance-power`), strictly inside (1, 2) — the
    /// compound-Poisson regime.
    pub tweedie_variance_power: f64,
    /// Error distribution of `survival:aft` (CLI `--aft-distribution`).
    pub aft_distribution: AftDistribution,
    /// Scale σ of `survival:aft` (CLI `--aft-sigma`), > 0.
    pub aft_sigma: f64,
    /// Column indices treated as categorical (CLI `--categorical 3,7` or
    /// `f3,f7`; csv loaders tag columns whose header name starts with
    /// `cat:`). Flagged columns must hold non-negative integral category
    /// codes in `[0, 64)`; the sketch then emits one bin per distinct
    /// category and the tree builder evaluates partition (set-membership)
    /// splits over those bins instead of ordered threshold splits.
    pub categorical_features: Vec<usize>,
}

impl Default for LearnerParams {
    fn default() -> Self {
        LearnerParams {
            objective: ObjectiveKind::SquaredError,
            num_class: 1,
            num_rounds: 50,
            eta: 0.3,
            max_depth: 6,
            max_leaves: 0,
            max_bins: 256,
            lambda: 1.0,
            gamma: 0.0,
            alpha: 0.0,
            min_child_weight: 1.0,
            grow_policy: GrowPolicy::DepthWise,
            n_devices: 1,
            compress: true,
            allreduce: AllReduce::Ring,
            eval_metric: None,
            eval_every: 1,
            early_stopping_rounds: 0,
            subsample: 1.0,
            colsample_bytree: 1.0,
            monotone_constraints: MonotoneConstraints::none(),
            seed: 0,
            verbose: false,
            threads: 0,
            batch_rows: crate::data::source::DEFAULT_BATCH_ROWS,
            max_resident_pages: 0,
            page_rows: crate::compress::page::DEFAULT_PAGE_ROWS,
            dist_rank: 0,
            dist_peers: Vec::new(),
            dist_payload: WirePayload::Quant,
            quantile_alpha: 0.5,
            tweedie_variance_power: 1.5,
            aft_distribution: AftDistribution::Normal,
            aft_sigma: 1.0,
            categorical_features: Vec::new(),
        }
    }
}

/// Parse a comma-separated feature-index list, accepting both `3,7` and
/// `f3,f7` spellings (the CLI/config `categorical` key).
pub fn parse_feature_list(s: &str) -> Result<Vec<usize>, String> {
    let t = s.trim();
    if t.is_empty() {
        return Ok(Vec::new());
    }
    t.split(',')
        .map(|tok| {
            let tok = tok.trim();
            let digits = tok.strip_prefix('f').unwrap_or(tok);
            digits
                .parse::<usize>()
                .map_err(|_| format!("categorical: cannot parse {tok:?} as a feature index"))
        })
        .collect()
}

impl LearnerParams {
    /// Read parameters from a [`Config`] (defaults for absent keys;
    /// unrelated keys are ignored, matching the CLI's merged config flow).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = LearnerParams::default();
        let objective: ObjectiveKind = match cfg.get("objective") {
            Some(s) => s.parse().expect("infallible"),
            None => d.objective,
        };
        let grow_policy: GrowPolicy = match cfg.get("grow_policy") {
            Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            None => d.grow_policy,
        };
        let allreduce: AllReduce = match cfg.get("allreduce") {
            Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            None => d.allreduce,
        };
        let eval_metric: Option<MetricKind> = match cfg.get("eval_metric") {
            None => None,
            Some("") => None,
            Some(s) => Some(s.parse().expect("infallible")),
        };
        let dist_payload: WirePayload = match cfg.get("dist_payload") {
            Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            None => d.dist_payload,
        };
        // comma-separated `host:port` list in rank order; empty = off
        let dist_peers: Vec<String> = match cfg.get("dist_peers") {
            None | Some("") => Vec::new(),
            Some(s) => s.split(',').map(|p| p.trim().to_string()).collect(),
        };
        let monotone_constraints: MonotoneConstraints = match cfg.get("monotone_constraints") {
            Some(s) => s
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))
                .context("monotone_constraints")?,
            None => MonotoneConstraints::none(),
        };
        let aft_distribution: AftDistribution = match cfg.get("aft_distribution") {
            Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            None => d.aft_distribution,
        };
        let categorical_features: Vec<usize> = match cfg.get("categorical") {
            None | Some("") => Vec::new(),
            Some(s) => parse_feature_list(s).map_err(|e| anyhow::anyhow!(e))?,
        };
        Ok(LearnerParams {
            objective,
            num_class: cfg.get_parse("num_class", d.num_class)?,
            num_rounds: cfg.get_parse("num_rounds", d.num_rounds)?,
            eta: cfg.get_parse("eta", d.eta)?,
            max_depth: cfg.get_parse("max_depth", d.max_depth)?,
            max_leaves: cfg.get_parse("max_leaves", d.max_leaves)?,
            max_bins: cfg.get_parse("max_bins", d.max_bins)?,
            lambda: cfg.get_parse("lambda", d.lambda)?,
            gamma: cfg.get_parse("gamma", d.gamma)?,
            alpha: cfg.get_parse("alpha", d.alpha)?,
            min_child_weight: cfg.get_parse("min_child_weight", d.min_child_weight)?,
            grow_policy,
            n_devices: cfg.get_parse("n_devices", d.n_devices)?,
            compress: cfg.get_bool("compress", d.compress),
            allreduce,
            eval_metric,
            eval_every: cfg.get_parse("eval_every", d.eval_every)?,
            early_stopping_rounds: cfg
                .get_parse("early_stopping_rounds", d.early_stopping_rounds)?,
            subsample: cfg.get_parse("subsample", d.subsample)?,
            colsample_bytree: cfg.get_parse("colsample_bytree", d.colsample_bytree)?,
            monotone_constraints,
            seed: cfg.get_parse("seed", d.seed)?,
            verbose: cfg.get_bool("verbose", d.verbose),
            threads: cfg.get_parse("threads", d.threads)?,
            batch_rows: cfg.get_parse("batch_rows", d.batch_rows)?,
            max_resident_pages: cfg.get_parse("max_resident_pages", d.max_resident_pages)?,
            page_rows: cfg.get_parse("page_rows", d.page_rows)?,
            dist_rank: cfg.get_parse("dist_rank", d.dist_rank)?,
            dist_peers,
            dist_payload,
            quantile_alpha: cfg.get_parse("quantile_alpha", d.quantile_alpha)?,
            tweedie_variance_power: cfg
                .get_parse("tweedie_variance_power", d.tweedie_variance_power)?,
            aft_distribution,
            aft_sigma: cfg.get_parse("aft_sigma", d.aft_sigma)?,
            categorical_features,
        })
    }

    /// The objective-shaping subset of this configuration — what the
    /// [`ObjectiveRegistry`] factories consume.
    pub fn objective_params(&self) -> ObjectiveParams {
        ObjectiveParams {
            num_class: self.num_class,
            quantile_alpha: self.quantile_alpha,
            tweedie_variance_power: self.tweedie_variance_power,
            aft_distribution: self.aft_distribution,
            aft_sigma: self.aft_sigma,
        }
    }

    /// Derive the coordinator configuration. Infallible now that every
    /// field is typed (the stringly-typed predecessor parsed here).
    pub fn coordinator_params(&self) -> CoordinatorParams {
        CoordinatorParams {
            n_devices: self.n_devices,
            compress: self.compress,
            tree: crate::tree::TreeParams {
                lambda: self.lambda,
                gamma: self.gamma,
                alpha: self.alpha,
                min_child_weight: self.min_child_weight,
                max_depth: self.max_depth,
                max_leaves: self.max_leaves,
                monotone_constraints: self.monotone_constraints.as_slice().to_vec(),
            },
            policy: self.grow_policy,
            allreduce: self.allreduce,
            cost: Default::default(),
            eta: self.eta,
            max_bins: self.max_bins,
            subtraction: true,
            colsample_bytree: self.colsample_bytree,
            seed: self.seed,
            threads: self.threads,
            max_resident_pages: self.max_resident_pages,
            page_rows: self.page_rows,
            categorical: self.categorical_features.clone(),
            dist: if self.dist_peers.is_empty() {
                None
            } else {
                Some(crate::comm::DistConfig {
                    rank: self.dist_rank,
                    peers: self.dist_peers.clone(),
                    payload: self.dist_payload,
                })
            },
        }
    }

    /// Every cross-field violation in this configuration, optionally
    /// checked against a known feature count. Empty means valid.
    pub fn validation_errors(&self, n_features: Option<usize>) -> Vec<String> {
        let mut errs = Vec::new();

        // objective / metric resolvability (registry-aware)
        if let ObjectiveKind::Custom(name) = &self.objective {
            if !ObjectiveRegistry::is_registered(name) {
                errs.push(format!(
                    "unknown objective {name:?}; valid objectives: {}",
                    ObjectiveRegistry::names().join(", ")
                ));
            }
        }
        if let Some(MetricKind::Custom(name)) = &self.eval_metric {
            if !MetricRegistry::is_registered(name) {
                errs.push(format!(
                    "unknown eval_metric {name:?}; valid metrics: {}",
                    MetricRegistry::names().join(", ")
                ));
            }
        }

        // multiclass cross-field rules
        if self.objective.is_multiclass() && self.num_class < 2 {
            errs.push(format!(
                "{} requires num_class >= 2, got {}",
                self.objective, self.num_class
            ));
        }
        if !self.objective.is_multiclass()
            && !matches!(self.objective, ObjectiveKind::Custom(_))
            && self.num_class > 1
        {
            errs.push(format!(
                "num_class = {} is only meaningful for multi:* objectives (objective is {})",
                self.num_class, self.objective
            ));
        }

        // growth-policy cross-field rules
        if self.grow_policy == GrowPolicy::DepthWise && self.max_depth == 0 {
            errs.push("grow_policy = depthwise requires max_depth >= 1".to_string());
        }
        if self.grow_policy == GrowPolicy::LossGuide && self.max_leaves < 2 {
            errs.push(format!(
                "grow_policy = lossguide requires max_leaves >= 2, got {}",
                self.max_leaves
            ));
        }
        if self.max_leaves == 1 {
            errs.push("max_leaves = 1 cannot admit any split".to_string());
        }

        // scalar ranges
        if self.num_rounds == 0 {
            errs.push("num_rounds must be >= 1".to_string());
        }
        let in_unit = |v: f64| v > 0.0 && v <= 1.0; // NaN fails both arms
        if !in_unit(self.eta) {
            errs.push(format!("eta must be in (0, 1], got {}", self.eta));
        }
        if self.max_bins < 2 {
            errs.push(format!("max_bins must be >= 2, got {}", self.max_bins));
        }
        if self.n_devices == 0 {
            errs.push("n_devices must be >= 1".to_string());
        }
        if !in_unit(self.subsample) {
            errs.push(format!("subsample must be in (0, 1], got {}", self.subsample));
        }
        if !in_unit(self.colsample_bytree) {
            errs.push(format!(
                "colsample_bytree must be in (0, 1], got {}",
                self.colsample_bytree
            ));
        }
        for (name, v) in [
            ("lambda", self.lambda),
            ("gamma", self.gamma),
            ("alpha", self.alpha),
            ("min_child_weight", self.min_child_weight),
        ] {
            if v < 0.0 || v.is_nan() {
                errs.push(format!("{name} must be >= 0, got {v}"));
            }
        }

        if self.batch_rows == 0 {
            errs.push("batch_rows must be >= 1".to_string());
        }

        // external-memory cross-field rules
        if self.max_resident_pages > 0 && !self.compress {
            errs.push(
                "max_resident_pages > 0 requires compress = true (spilled pages are \
                 bit-packed)"
                    .to_string(),
            );
        }
        if self.page_rows == 0 {
            errs.push("page_rows must be >= 1".to_string());
        }

        // distributed cross-field rules (off while dist_peers is empty)
        if !self.dist_peers.is_empty() {
            if self.dist_peers.len() < 2 {
                errs.push(format!(
                    "dist_peers lists {} address; distributed training needs at least 2 \
                     ranks (drop the flag to train in one process)",
                    self.dist_peers.len()
                ));
            }
            if self.dist_rank >= self.dist_peers.len() {
                errs.push(format!(
                    "dist_rank = {} is out of range for {} peers (ranks are 0-based)",
                    self.dist_rank,
                    self.dist_peers.len()
                ));
            }
            if self.n_devices != self.dist_peers.len() {
                errs.push(format!(
                    "distributed runs need n_devices ({}) == number of dist_peers ({}): \
                     each rank owns exactly one device shard",
                    self.n_devices,
                    self.dist_peers.len()
                ));
            }
            if self.allreduce != AllReduce::Ring {
                errs.push(format!(
                    "distributed mode implements the ring schedule only (got allreduce = {})",
                    self.allreduce
                ));
            }
        }

        // objective-shaping parameters (checked unconditionally — they
        // have well-defined ranges whether or not the objective uses them)
        let strictly_inside = |v: f64, lo: f64, hi: f64| v > lo && v < hi; // NaN fails
        if !strictly_inside(self.quantile_alpha, 0.0, 1.0) {
            errs.push(format!(
                "quantile_alpha must be in (0, 1), got {}",
                self.quantile_alpha
            ));
        }
        if !strictly_inside(self.tweedie_variance_power, 1.0, 2.0) {
            errs.push(format!(
                "tweedie_variance_power must be in (1, 2), got {}",
                self.tweedie_variance_power
            ));
        }
        if !(self.aft_sigma > 0.0 && self.aft_sigma.is_finite()) {
            errs.push(format!("aft_sigma must be > 0, got {}", self.aft_sigma));
        }

        // categorical feature list: indices must be distinct (and in range
        // when the feature count is known this early)
        let mut seen_cat = std::collections::BTreeSet::new();
        for &f in &self.categorical_features {
            if !seen_cat.insert(f) {
                errs.push(format!("categorical lists feature {f} more than once"));
            }
            if let Some(n) = n_features {
                if f >= n {
                    errs.push(format!(
                        "categorical feature index {f} is out of range (data has {n} features)"
                    ));
                }
            }
        }

        // evaluation cadence
        if self.early_stopping_rounds > 0 && self.eval_every == 0 {
            errs.push(
                "early_stopping_rounds > 0 requires eval_every >= 1 (eval_every = 0 \
                 evaluates only after the final round)"
                    .to_string(),
            );
        }

        // constraints vs feature count (when known this early)
        if let Some(n) = n_features {
            if let Err(e) = self.monotone_constraints.check_n_features(n) {
                errs.push(e);
            }
        }

        errs
    }

    /// Validate the full cross-field matrix, returning **all** violations.
    pub fn validate(&self) -> Result<(), ValidationErrors> {
        let errs = self.validation_errors(None);
        if errs.is_empty() {
            Ok(())
        } else {
            Err(ValidationErrors(errs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_display_fromstr_round_trip() {
        for name in ObjectiveKind::BUILTIN_NAMES {
            let k: ObjectiveKind = name.parse().unwrap();
            assert_eq!(k.to_string(), name, "canonical name must round-trip");
            let again: ObjectiveKind = k.to_string().parse().unwrap();
            assert_eq!(again, k);
        }
        // alias canonicalises
        let k: ObjectiveKind = "reg:linear".parse().unwrap();
        assert_eq!(k, ObjectiveKind::SquaredError);
        // unknown name survives as Custom and round-trips
        let k: ObjectiveKind = "my:loss".parse().unwrap();
        assert_eq!(k, ObjectiveKind::Custom("my:loss".into()));
        assert_eq!(k.to_string(), "my:loss");
    }

    #[test]
    fn metric_display_fromstr_round_trip() {
        for name in MetricKind::BUILTIN_NAMES {
            let k: MetricKind = name.parse().unwrap();
            assert_eq!(k.to_string(), name);
        }
        let k: MetricKind = "acc".parse().unwrap();
        assert_eq!(k, MetricKind::Accuracy);
    }

    #[test]
    fn monotone_parse_and_display() {
        let m: MonotoneConstraints = "1,0,-1".parse().unwrap();
        assert_eq!(m.as_slice(), &[1, 0, -1]);
        assert_eq!(m.to_string(), "(1,0,-1)");
        let again: MonotoneConstraints = m.to_string().parse().unwrap();
        assert_eq!(again, m);
        let empty: MonotoneConstraints = "".parse().unwrap();
        assert!(empty.is_empty());
        assert_eq!(empty.to_string(), "");
        assert!("2,0".parse::<MonotoneConstraints>().is_err());
        assert!("abc".parse::<MonotoneConstraints>().is_err());
        let parenthesised: MonotoneConstraints = "(1, -1, 0)".parse().unwrap();
        assert_eq!(parenthesised.as_slice(), &[1, -1, 0]);
    }

    #[test]
    fn monotone_rejects_overlong_lists() {
        let m: MonotoneConstraints = "1,0,-1,1".parse().unwrap();
        assert!(m.check_n_features(3).is_err());
        assert!(m.check_n_features(4).is_ok());
        let p = LearnerParams {
            monotone_constraints: m,
            ..Default::default()
        };
        assert!(!p.validation_errors(Some(3)).is_empty());
        assert!(p.validation_errors(Some(10)).is_empty());
    }

    #[test]
    fn default_params_validate_clean() {
        assert!(LearnerParams::default().validate().is_ok());
    }

    #[test]
    fn validate_reports_every_violation_at_once() {
        let p = LearnerParams {
            objective: ObjectiveKind::MultiSoftmax,
            num_class: 1,                  // violation 1: multi needs >= 2
            eta: 0.0,                      // violation 2
            subsample: 1.5,                // violation 3
            grow_policy: GrowPolicy::LossGuide,
            max_leaves: 0,                 // violation 4
            ..Default::default()
        };
        let errs = p.validation_errors(None);
        assert!(errs.len() >= 4, "want all violations, got {errs:?}");
        let joined = errs.join("\n");
        assert!(joined.contains("num_class"), "{joined}");
        assert!(joined.contains("eta"), "{joined}");
        assert!(joined.contains("subsample"), "{joined}");
        assert!(joined.contains("max_leaves"), "{joined}");
    }

    #[test]
    fn unknown_objective_lists_valid_names() {
        let p = LearnerParams {
            objective: ObjectiveKind::Custom("no:such".into()),
            ..Default::default()
        };
        let errs = p.validation_errors(None);
        assert_eq!(errs.len(), 1);
        assert!(errs[0].contains("reg:squarederror"), "{}", errs[0]);
        assert!(errs[0].contains("rank:pairwise"), "{}", errs[0]);
    }

    #[test]
    fn paging_requires_compress() {
        let p = LearnerParams {
            max_resident_pages: 2,
            compress: false,
            ..Default::default()
        };
        let errs = p.validation_errors(None);
        assert_eq!(errs.len(), 1);
        assert!(errs[0].contains("compress"), "{}", errs[0]);
        let ok = LearnerParams {
            max_resident_pages: 2,
            compress: true,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        let bad_page = LearnerParams {
            page_rows: 0,
            ..Default::default()
        };
        assert!(!bad_page.validation_errors(None).is_empty());
    }

    #[test]
    fn dist_rules_only_apply_when_peers_listed() {
        // no peers: dist_rank/dist_payload are inert and nothing fires
        let off = LearnerParams {
            dist_rank: 7,
            ..Default::default()
        };
        assert!(off.validate().is_ok());
        assert!(off.coordinator_params().dist.is_none());

        let peers = vec!["127.0.0.1:7001".to_string(), "127.0.0.1:7002".to_string()];
        let ok = LearnerParams {
            dist_peers: peers.clone(),
            dist_rank: 1,
            n_devices: 2,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        let cp = ok.coordinator_params();
        let dist = cp.dist.expect("peers listed => dist config");
        assert_eq!(dist.rank, 1);
        assert_eq!(dist.peers, peers);
        assert_eq!(dist.payload, WirePayload::Quant);

        // every cross-field rule fires at once
        let bad = LearnerParams {
            dist_peers: vec!["127.0.0.1:7001".to_string()], // violation: < 2 ranks
            dist_rank: 3,                                   // violation: out of range
            n_devices: 4,                                   // violation: != peers.len()
            allreduce: AllReduce::Serial,                   // violation: ring only
            ..Default::default()
        };
        let errs = bad.validation_errors(None);
        assert!(errs.len() >= 4, "want all dist violations, got {errs:?}");
        let joined = errs.join("\n");
        assert!(joined.contains("at least 2"), "{joined}");
        assert!(joined.contains("out of range"), "{joined}");
        assert!(joined.contains("n_devices"), "{joined}");
        assert!(joined.contains("ring"), "{joined}");
    }

    #[test]
    fn from_config_reads_dist_fields() {
        let cfg = Config::from_str_contents(
            "dist_rank = 2\ndist_peers = \"127.0.0.1:9001, 127.0.0.1:9002,127.0.0.1:9003\"\n\
             dist_payload = raw\nn_devices = 3\n",
        )
        .unwrap();
        let p = LearnerParams::from_config(&cfg).unwrap();
        assert_eq!(p.dist_rank, 2);
        assert_eq!(
            p.dist_peers,
            vec!["127.0.0.1:9001", "127.0.0.1:9002", "127.0.0.1:9003"]
        );
        assert_eq!(p.dist_payload, WirePayload::Raw);
        assert!(p.validate().is_ok());

        let bad = Config::from_str_contents("dist_payload = morse\n").unwrap();
        assert!(LearnerParams::from_config(&bad).is_err());
    }

    #[test]
    fn early_stopping_requires_eval_cadence() {
        let p = LearnerParams {
            early_stopping_rounds: 3,
            eval_every: 0,
            ..Default::default()
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn from_config_reads_typed_fields() {
        let cfg = Config::from_str_contents(
            "objective = binary:logistic\nnum_rounds = 7\neta = 0.1\ncompress = false\n\
             grow_policy = lossguide\nallreduce = serial\neval_metric = auc\n\
             monotone_constraints = \"(1,0,-1)\"\nmax_leaves = 8\n",
        )
        .unwrap();
        let p = LearnerParams::from_config(&cfg).unwrap();
        assert_eq!(p.objective, ObjectiveKind::BinaryLogistic);
        assert_eq!(p.num_rounds, 7);
        assert_eq!(p.eta, 0.1);
        assert!(!p.compress);
        assert_eq!(p.grow_policy, GrowPolicy::LossGuide);
        assert_eq!(p.allreduce, AllReduce::Serial);
        assert_eq!(p.eval_metric, Some(MetricKind::Auc));
        assert_eq!(p.monotone_constraints.as_slice(), &[1, 0, -1]);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn objective_param_ranges_validated() {
        for (p, needle) in [
            (
                LearnerParams {
                    quantile_alpha: 1.0,
                    ..Default::default()
                },
                "quantile_alpha",
            ),
            (
                LearnerParams {
                    quantile_alpha: 0.0,
                    ..Default::default()
                },
                "quantile_alpha",
            ),
            (
                LearnerParams {
                    tweedie_variance_power: 2.0,
                    ..Default::default()
                },
                "tweedie_variance_power",
            ),
            (
                LearnerParams {
                    tweedie_variance_power: 1.0,
                    ..Default::default()
                },
                "tweedie_variance_power",
            ),
            (
                LearnerParams {
                    aft_sigma: 0.0,
                    ..Default::default()
                },
                "aft_sigma",
            ),
        ] {
            let errs = p.validation_errors(None);
            assert_eq!(errs.len(), 1, "{needle}: {errs:?}");
            assert!(errs[0].contains(needle), "{}", errs[0]);
        }
        // in-range values are clean
        let ok = LearnerParams {
            objective: ObjectiveKind::QuantileReg,
            quantile_alpha: 0.9,
            tweedie_variance_power: 1.2,
            aft_sigma: 2.0,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn categorical_list_parses_and_validates() {
        assert_eq!(parse_feature_list("3,7").unwrap(), vec![3, 7]);
        assert_eq!(parse_feature_list("f3, f7").unwrap(), vec![3, 7]);
        assert_eq!(parse_feature_list("").unwrap(), Vec::<usize>::new());
        assert!(parse_feature_list("f3,x").is_err());

        let dup = LearnerParams {
            categorical_features: vec![2, 2],
            ..Default::default()
        };
        assert!(dup.validation_errors(None)[0].contains("more than once"));
        let oob = LearnerParams {
            categorical_features: vec![5],
            ..Default::default()
        };
        assert!(oob.validation_errors(None).is_empty());
        assert!(oob.validation_errors(Some(4))[0].contains("out of range"));
        assert_eq!(oob.coordinator_params().categorical, vec![5]);
    }

    #[test]
    fn from_config_reads_scenario_fields() {
        let cfg = Config::from_str_contents(
            "objective = survival:aft\naft_distribution = logistic\naft_sigma = 0.5\n\
             quantile_alpha = 0.9\ntweedie_variance_power = 1.3\ncategorical = \"f1,f4\"\n",
        )
        .unwrap();
        let p = LearnerParams::from_config(&cfg).unwrap();
        assert_eq!(p.objective, ObjectiveKind::SurvivalAft);
        assert_eq!(p.aft_distribution, AftDistribution::Logistic);
        assert_eq!(p.aft_sigma, 0.5);
        assert_eq!(p.quantile_alpha, 0.9);
        assert_eq!(p.tweedie_variance_power, 1.3);
        assert_eq!(p.categorical_features, vec![1, 4]);
        assert!(p.validate().is_ok());
        let op = p.objective_params();
        assert_eq!(op.aft_distribution, AftDistribution::Logistic);
        assert_eq!(op.quantile_alpha, 0.9);

        let bad = Config::from_str_contents("aft_distribution = cauchy\n").unwrap();
        assert!(LearnerParams::from_config(&bad).is_err());
        let bad = Config::from_str_contents("categorical = banana\n").unwrap();
        assert!(LearnerParams::from_config(&bad).is_err());
    }

    #[test]
    fn from_config_rejects_bad_enum_text() {
        let cfg = Config::from_str_contents("grow_policy = sideways\n").unwrap();
        assert!(LearnerParams::from_config(&cfg).is_err());
        let cfg = Config::from_str_contents("allreduce = carrier-pigeon\n").unwrap();
        assert!(LearnerParams::from_config(&cfg).is_err());
        let cfg = Config::from_str_contents("monotone_constraints = 9,9\n").unwrap();
        assert!(LearnerParams::from_config(&cfg).is_err());
    }
}
