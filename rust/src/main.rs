//! `xgb-tpu` — command-line launcher for the multi-device gradient
//! boosting system (leader entrypoint).
//!
//! Subcommands:
//!
//! * `train`     — train on a synthetic Table-1 dataset or a CSV/LibSVM
//!                 file; all XGBoost-style parameters available as flags.
//!                 With `--stream`, files are ingested through the
//!                 out-of-core two-pass pipeline (`--batch-rows` bounds
//!                 peak transient memory; the model is bit-identical).
//! * `predict`   — score rows with a saved model. `--stream` quantises
//!                 each batch against the model's frozen cuts and scores
//!                 it from the compressed representation (O(batch)
//!                 memory); `--max-resident-pages N` packs the input
//!                 into spilled ELLPACK pages and traverses them under
//!                 the budget. All paths print a bit-exact prediction
//!                 checksum and agree (one warned exception: sparse
//!                 inputs with values above the training range clamp on
//!                 the paged path).
//! * `eval`      — evaluate a metric over a labelled file through the
//!                 same three paths.
//! * `serve`     — low-latency online scoring: load a saved model into
//!                 the flat SoA forest, answer line-based requests
//!                 (dense CSV or sparse `idx:val`) over stdin/stdout or
//!                 TCP (`--listen`), micro-batching them on the exec
//!                 pool. Responses are bit-identical to `predict` (same
//!                 checksum line); `!reload` or `--reload-poll-ms`
//!                 hot-swaps the model file without dropping requests.
//! * `export`    — write a synthetic dataset to CSV/LibSVM (streaming
//!                 smoke-test fodder).
//! * `datasets`  — print the Table 1 dataset registry.
//! * `info`      — show AOT artifact manifest + PJRT platform.
//! * `help`      — this text.
//!
//! Examples:
//!
//! ```text
//! xgb-tpu train --dataset higgs --rows 100000 --num-rounds 50 \
//!     --n-devices 8 --grow-policy depthwise --compress true
//! xgb-tpu train --csv data.csv --label-col 0 --objective reg:squarederror
//! xgb-tpu train --libsvm data.libsvm --stream --batch-rows 65536
//! xgb-tpu train --dataset higgs --rows 20000 --backend xla
//! xgb-tpu export --dataset bosch --rows 10000 --format libsvm --out b.libsvm
//! ```

use anyhow::{bail, Context, Result};
use xgb_tpu::bench::Table;
use xgb_tpu::coordinator::NativeBackend;
use xgb_tpu::data::synthetic::{self, DatasetSpec};
use xgb_tpu::data::{load_csv, load_libsvm, Dataset};
use xgb_tpu::gbm::{Learner, LearnerParams, ObjectiveKind};
use xgb_tpu::runtime::{Artifacts, XlaHistBackend};
use xgb_tpu::util::{ArgParser, Config};

fn main() {
    let args = ArgParser::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "train" => run_train(&args),
        "predict" => run_predict(&args),
        "eval" => run_eval(&args),
        "serve" => run_serve(&args),
        "export" => run_export(&args),
        "datasets" => run_datasets(),
        "info" => run_info(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "xgb-tpu — multi-device gradient boosting (XGBoost GPU paper reproduction)\n\n\
         USAGE: xgb-tpu <train|predict|eval|serve|export|datasets|info> [--flag value ...]\n\n\
         train flags:\n\
           --dataset <name>       synthetic dataset (see `xgb-tpu datasets`)\n\
           --rows <n>             synthetic row count (default 20000)\n\
           --csv <path>           train from CSV (--label-col, --header)\n\
           --libsvm <path>        train from LibSVM file\n\
           --config <path>        key=value parameter file\n\
           --objective <name>     reg:squarederror|binary:logistic|multi:softmax|\n\
                                  multi:softprob|rank:pairwise|reg:quantile|\n\
                                  reg:tweedie|survival:aft\n\
           --quantile-alpha <f>   target quantile of reg:quantile, in (0,1)\n\
                                  (default 0.5; eval metric pinball@alpha)\n\
           --tweedie-variance-power <f>  variance power of reg:tweedie, in\n\
                                  (1,2) (default 1.5)\n\
           --aft-distribution normal|logistic  error distribution of\n\
                                  survival:aft (default normal)\n\
           --aft-sigma <f>        scale of the AFT error distribution (>0,\n\
                                  default 1)\n\
           --categorical <list>   comma-separated feature indices (`3,7` or\n\
                                  `f3,f7`) treated as categorical: integer\n\
                                  codes in [0,64), one bin per category,\n\
                                  membership (bitset) splits. A CSV trained\n\
                                  with --header auto-flags columns whose\n\
                                  header cell starts with `cat:`\n\
           --resume <path>        continue boosting from a saved model:\n\
                                  loads it, reuses its frozen cuts (the new\n\
                                  data is quantised against the original\n\
                                  grid, never re-sketched) and boosts\n\
                                  --num-rounds further rounds. Objective\n\
                                  (with its shaping flags) and --max-bins\n\
                                  must match the saved model. train(a) +\n\
                                  resume(b) is bit-identical to train(a+b)\n\
           --num-rounds <n>       boosting rounds (default 50)\n\
           --eta, --max-depth, --max-leaves, --max-bins, --lambda, --gamma,\n\
           --alpha, --min-child-weight, --num-class, --eval-metric,\n\
           --grow-policy depthwise|lossguide, --early-stopping-rounds\n\
           --n-devices <p>        simulated devices (default 1)\n\
           --threads <n>          worker threads for the parallel engine\n\
                                  (0 = all cores, 1 = serial; results are\n\
                                  bit-identical for every value)\n\
           --compress <bool>      bit-packed shards (default true)\n\
           --allreduce ring|serial\n\
           --dist-peers <list>    comma-separated host:port listen address of\n\
                                  every rank, in rank order. Engages real\n\
                                  multi-process training: each listed process\n\
                                  runs `train` with the same data and flags\n\
                                  plus its own --dist-rank, builds only its\n\
                                  rank's device histograms, and merges them\n\
                                  over a TCP ring all-reduce. Requires\n\
                                  --n-devices == number of peers and the ring\n\
                                  algorithm; trees are bit-identical to a\n\
                                  single-process run with the same --n-devices\n\
           --dist-rank <r>        this process's 0-based rank in --dist-peers\n\
           --dist-payload quant|raw  wire encoding for histogram chunks\n\
                                  (default quant: lossless bit-packing via the\n\
                                  compression machinery; raw ships plain f64)\n\
           --backend native|xla   histogram execution engine\n\
           --stream               out-of-core ingestion: stream the input\n\
                                  through the two-pass sketch/quantise/pack\n\
                                  pipeline instead of materializing it (no\n\
                                  shuffled holdout; model is bit-identical\n\
                                  to the in-memory run on the same rows)\n\
           --batch-rows <n>       rows per streamed batch (default 65536);\n\
                                  bounds peak transient memory only\n\
           --max-resident-pages <n>  external-memory budget: packed pages\n\
                                  each device shard keeps resident (0 =\n\
                                  fully resident, the default). With a\n\
                                  budget, shards spill sealed pages to a\n\
                                  temp file and histogram rounds stream\n\
                                  them back with async prefetch; the\n\
                                  model is bit-identical either way\n\
           --page-rows <n>        rows per spilled page (default 65536)\n\
           --valid-frac <f>       holdout fraction when training from files\n\
                                  (0 = train on all rows in file order)\n\
           --subsample <f>        row sampling rate per tree\n\
           --colsample-bytree <f> feature sampling rate per tree\n\
           --monotone-constraints \"1,0,-1\"  per-feature monotonicity\n\
           --model-out <path>     save the trained model (text format)\n\
           --log-file <path>      per-round training telemetry: round, metric,\n\
                                  train/valid value, wall-secs — CSV, or JSONL\n\
                                  when the path ends .json/.jsonl\n\
           --importance [gain|cover|weight]  print feature importance\n\
           --seed <n>\n\n\
         predict flags:\n\
           --model <path>         model saved by train --model-out\n\
           --csv/--libsvm <path>  rows to score (--label-col ignored labels ok)\n\
           --out <path>           write one prediction per line (default stdout)\n\
           --backend native|xla   prediction engine (§2.4)\n\
           --stream               quantised streaming prediction: score each\n\
                                  batch straight from the model's frozen cuts\n\
                                  (O(batch x cols) transient memory; predictions\n\
                                  bit-identical to the float path)\n\
           --max-resident-pages <n>  external-memory prediction: quantise+pack\n\
                                  the input into spilled pages, then traverse\n\
                                  under the n-page residency budget\n\
           --page-rows <n>        rows per spilled page for the paged path\n\
           --batch-rows <n>       rows per streamed batch\n\
           --threads <n>          worker threads (0 = all cores)\n\
           (every path prints `predictions: n=... checksum=...` to stderr —\n\
            float, --stream and --max-resident-pages agree bit for bit; the\n\
            one exception is warned: sparse inputs with values above the\n\
            training range clamp on the paged path)\n\n\
         eval flags:\n\
           --model <path>         model saved by train --model-out\n\
           --csv/--libsvm <path>  labelled rows to evaluate\n\
           --metric <name>        metric (default: the objective's default)\n\
           --stream / --max-resident-pages / --page-rows / --batch-rows /\n\
           --threads              same compressed paths as predict\n\n\
         serve flags:\n\
           --model <path>         model saved by train --model-out (must carry\n\
                                  the cuts section; legacy files are rejected\n\
                                  with a retrain/re-save error)\n\
           --listen <addr:port>   serve TCP connections instead of stdin/stdout\n\
           --batch-max <n>        rows coalesced per scored micro-batch (default 64)\n\
           --batch-wait-us <n>    max wait for an open batch to fill (default 200)\n\
           --queue-cap <n>        bounded queue depth = backpressure (default 1024)\n\
           --threads <n>          scorer pool width (0 = all cores)\n\
           --reload-poll-ms <n>   poll the model file's mtime and hot-swap on\n\
                                  change (0 = off; `!reload` always works)\n\
           --col-base <n>         subtracted from sparse request indices\n\
                                  (1 for LibSVM-style 1-based requests)\n\
           request lines: dense `0.5,,3.2` (empty/na/nan/? = missing) or\n\
           sparse `3:1.5 17:0.25`; verbs: !reload !stats !quit !shutdown.\n\
           One response line per request, in request order, bit-identical\n\
           to predict (same `predictions:` checksum line on shutdown)\n\n\
         export flags:\n\
           --dataset <name>       synthetic dataset to write\n\
           --rows <n>             row count (default 20000)\n\
           --format csv|libsvm    output format (default libsvm)\n\
           --out <path>           destination file\n\
           --seed <n>\n"
    );
}

/// Load the model named by `--model`, applying the `--threads` override.
fn load_predict_model(args: &ArgParser) -> Result<xgb_tpu::gbm::Booster> {
    let model_path = args.get("model").context("--model required")?;
    let mut booster = xgb_tpu::gbm::load_model_file(model_path)?;
    if args.has("threads") {
        booster.params.threads = args.get_parse("threads", 0usize);
    }
    Ok(booster)
}

/// Load the `--csv`/`--libsvm` input fully in memory (the float
/// prediction/eval path).
fn load_predict_dataset(args: &ArgParser) -> Result<Dataset> {
    if let Some(path) = args.get("csv") {
        load_csv(path, args.get_parse("label-col", 0usize), args.flag("header"))
    } else if let Some(path) = args.get("libsvm") {
        load_libsvm(path)
    } else {
        bail!("needs --csv or --libsvm")
    }
}

/// Open the `--csv`/`--libsvm` input as a streaming [`BatchSource`] (the
/// compressed prediction paths never materialize the float matrix).
fn open_predict_source(
    args: &ArgParser,
    batch_rows: usize,
) -> Result<Box<dyn xgb_tpu::data::BatchSource>> {
    use xgb_tpu::data::{CsvSource, LibsvmSource};
    if let Some(path) = args.get("csv") {
        Ok(Box::new(CsvSource::open(
            path,
            args.get_parse("label-col", 0usize),
            args.flag("header"),
            batch_rows,
        )?))
    } else if let Some(path) = args.get("libsvm") {
        Ok(Box::new(LibsvmSource::open(path, batch_rows)?))
    } else {
        bail!("needs --csv or --libsvm")
    }
}

fn run_predict(args: &ArgParser) -> Result<()> {
    let booster = load_predict_model(args)?;
    let backend = args.get_str("backend", "native");
    let budget: usize = args.get_parse("max-resident-pages", 0usize);
    let batch_rows: usize = args.get_parse("batch-rows", booster.params.batch_rows);
    anyhow::ensure!(
        !(args.flag("stream") && budget > 0),
        "--stream and --max-resident-pages select different prediction paths; pass one"
    );

    let preds: Vec<f32> = if args.flag("stream") {
        // streaming quantised prediction: one pass, O(batch x cols)
        // transient bytes, bit-identical to the float path
        anyhow::ensure!(backend == "native", "--stream uses the native engine");
        let mut src = open_predict_source(args, batch_rows)?;
        let (preds, sm) = booster.predict_stream(src.as_mut())?;
        eprintln!(
            "streamed {} rows in {} batches; peak transient {:.2} MB",
            sm.n_rows,
            sm.n_batches,
            sm.peak_transient_bytes as f64 / 1e6
        );
        preds
    } else if budget > 0 {
        // external-memory prediction: pack to spilled pages, traverse
        // under the residency budget
        anyhow::ensure!(backend == "native", "--max-resident-pages uses the native engine");
        let page_rows: usize = args.get_parse("page-rows", booster.params.page_rows);
        let mut src = open_predict_source(args, batch_rows)?;
        let (preds, packed) = booster.predict_paged(src.as_mut(), page_rows, budget)?;
        if packed.clamped_values > 0 {
            eprintln!(
                "warning: {} sparse value(s) at/above the training range clamped into \
                 their feature's last bin; rows containing them may route differently \
                 from the float path (dense inputs never clamp)",
                packed.clamped_values
            );
        }
        let stats = packed.store.take_round_stats();
        eprintln!(
            "paged prediction: {} pages loaded ({:.3}s I/O, {:.3}s blocked), \
             peak resident {:.2} MB (budget {budget} pages x {page_rows} rows)",
            stats.pages_loaded,
            stats.load_secs,
            stats.wait_secs,
            stats.peak_resident_bytes as f64 / 1e6
        );
        preds
    } else {
        let ds = load_predict_dataset(args)?;
        match backend.as_str() {
            "native" => booster.predict(&ds.x),
            "xla" => {
                // margins through the AOT predict artifact, then transform
                let artifacts = std::sync::Arc::new(Artifacts::discover()?);
                let predictor = xgb_tpu::runtime::XlaPredictor::new(artifacts);
                anyhow::ensure!(
                    booster.trees.len() == 1,
                    "xla predict path supports single-output models"
                );
                let margins =
                    predictor.predict_margins(&booster.trees[0], booster.base_score[0], &ds.x)?;
                if booster.params.objective == ObjectiveKind::BinaryLogistic {
                    margins.iter().map(|&m| 1.0 / (1.0 + (-m).exp())).collect()
                } else {
                    margins
                }
            }
            other => bail!("unknown backend {other:?}"),
        }
    };

    // cross-path parity fingerprint: float, --stream and
    // --max-resident-pages runs over the same input must print the same
    // line (ci.sh enforces it)
    eprintln!(
        "predictions: n={} checksum={:#018x}",
        preds.len(),
        xgb_tpu::predict::prediction_checksum(&preds)
    );
    match args.get("out") {
        Some(path) => {
            let mut out = String::with_capacity(preds.len() * 12);
            for p in &preds {
                out.push_str(&format!("{p}\n"));
            }
            std::fs::write(path, out)?;
            eprintln!("wrote {} predictions to {path}", preds.len());
        }
        None => {
            for p in &preds {
                println!("{p}");
            }
        }
    }
    Ok(())
}

/// `eval` — score a labelled file against a saved model and print one
/// metric line, through any of the three prediction paths (float,
/// streaming-quantised, paged-quantised). The metric value is printed
/// with full precision so paths can be compared exactly.
fn run_eval(args: &ArgParser) -> Result<()> {
    let booster = load_predict_model(args)?;
    let metric = match args.get("metric") {
        Some(m) => m.to_string(),
        None => booster.default_metric().to_string(),
    };
    let budget: usize = args.get_parse("max-resident-pages", 0usize);
    let batch_rows: usize = args.get_parse("batch-rows", booster.params.batch_rows);
    anyhow::ensure!(
        !(args.flag("stream") && budget > 0),
        "--stream and --max-resident-pages select different eval paths; pass one"
    );
    let value = if args.flag("stream") {
        let mut src = open_predict_source(args, batch_rows)?;
        booster.evaluate_from_source(src.as_mut(), &metric)?
    } else if budget > 0 {
        let page_rows: usize = args.get_parse("page-rows", booster.params.page_rows);
        let mut src = open_predict_source(args, batch_rows)?;
        let (value, clamped) = booster.evaluate_paged(src.as_mut(), &metric, page_rows, budget)?;
        if clamped > 0 {
            eprintln!(
                "warning: {clamped} sparse value(s) at/above the training range clamped \
                 into their feature's last bin; the metric may differ from the float path"
            );
        }
        value
    } else {
        let ds = load_predict_dataset(args)?;
        booster.evaluate(&ds, &metric)?
    };
    println!("eval {metric}={value}");
    Ok(())
}

/// `serve` — low-latency online scoring (see `xgb_tpu::serve`). Default
/// transport is stdin/stdout (one request line in, one response line
/// out); `--listen addr:port` accepts TCP connections instead, one
/// stream each, all feeding the shared micro-batch queue.
fn run_serve(args: &ArgParser) -> Result<()> {
    use std::time::Duration;
    use xgb_tpu::serve::{ModelRegistry, ServeOptions, Server};

    let model_path = args.get("model").context("--model required")?;
    let opts = ServeOptions {
        batch_max: args.get_parse("batch-max", 64usize),
        batch_wait: Duration::from_micros(args.get_parse("batch-wait-us", 200u64)),
        queue_cap: args.get_parse("queue-cap", 1024usize),
        threads: args.get_parse("threads", 0usize),
        col_base: args.get_parse("col-base", 0u32),
    };
    let poll_ms: u64 = args.get_parse("reload-poll-ms", 0u64);
    let reload_poll = (poll_ms > 0).then(|| Duration::from_millis(poll_ms));
    // fail-fast here: a legacy cuts-less model is rejected before any
    // request is accepted, with the retrain/re-save fix in the message
    let registry = std::sync::Arc::new(ModelRegistry::open(model_path)?);
    {
        let m = registry.current();
        eprintln!(
            "serving {model_path} (epoch {}): {} features, {} trees, {} nodes, \
             {:.1} KB flat forest",
            m.epoch,
            m.n_features(),
            m.flat().n_trees(),
            m.flat().n_nodes(),
            m.flat().bytes() as f64 / 1e3,
        );
    }
    let server = Server::start(registry, opts, reload_poll);

    if let Some(addr) = args.get("listen") {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding serve listener on {addr}"))?;
        eprintln!("listening on {addr} (a stream's `!shutdown` stops the server)");
        server.serve_tcp(listener)?;
        let stats = server.shutdown();
        eprintln!("{}", stats.render());
    } else {
        let stdin = std::io::stdin();
        let summary = server.serve_stream(stdin.lock(), std::io::stdout())?;
        let stats = server.shutdown();
        eprintln!("{}", stats.render());
        // byte-identical to `predict`'s checksum line over the same
        // rows — ci.sh compares the two
        eprintln!("{}", summary.prediction_line());
    }
    Ok(())
}

fn learner_params_from_args(args: &ArgParser) -> Result<LearnerParams> {
    // config file first, CLI overrides
    let mut cfg = Config::new();
    if let Some(path) = args.get("config") {
        cfg = Config::from_file(path)?;
    }
    for (k, v) in args.iter() {
        // CLI flags use dashes; config keys use underscores
        cfg.set(k.replace('-', "_"), v);
    }
    let mut p = LearnerParams::from_config(&cfg)?;
    p.verbose = true;
    Ok(p)
}

fn load_dataset(args: &ArgParser) -> Result<(Dataset, Option<Dataset>, Option<DatasetSpec>)> {
    let valid_frac: f64 = args.get_parse("valid-frac", 0.2);
    let seed: u64 = args.get_parse("seed", 42u64);
    if let Some(name) = args.get("dataset") {
        let rows: usize = args.get_parse("rows", 20_000usize);
        let spec = DatasetSpec::by_name(name, rows)
            .with_context(|| format!("unknown dataset {name:?}; see `xgb-tpu datasets`"))?;
        let g = synthetic::generate(&spec, seed);
        return Ok((g.train, Some(g.valid), Some(spec)));
    }
    if let Some(path) = args.get("csv") {
        let ds = load_csv(
            path,
            args.get_parse("label-col", 0usize),
            args.flag("header"),
        )?;
        return Ok(split_or_whole(ds, valid_frac, seed));
    }
    if let Some(path) = args.get("libsvm") {
        let ds = load_libsvm(path)?;
        return Ok(split_or_whole(ds, valid_frac, seed));
    }
    bail!("no input: pass --dataset, --csv or --libsvm")
}

/// `valid_frac = 0` keeps the file's row order intact (no shuffle), which
/// is what makes the in-memory run comparable bit-for-bit with
/// `--stream` on the same file.
fn split_or_whole(
    ds: Dataset,
    valid_frac: f64,
    seed: u64,
) -> (Dataset, Option<Dataset>, Option<DatasetSpec>) {
    if valid_frac <= 0.0 {
        (ds, None, None)
    } else {
        let (train, valid) = ds.split(valid_frac, seed);
        (train, Some(valid), None)
    }
}

/// Dataset-aware defaults (objective/num_class/eval_metric from the
/// synthetic spec's task) unless the user overrode them — shared by the
/// in-memory and streaming train paths so they cannot drift.
fn apply_spec_defaults(params: &mut LearnerParams, spec: &DatasetSpec, args: &ArgParser) {
    if !args.has("objective") {
        params.objective = spec.task.objective().parse().expect("infallible");
    }
    if !args.has("num-class") {
        params.num_class = spec.task.num_class();
    }
    if !args.has("eval-metric") {
        params.eval_metric = Some(spec.task.metric().parse().expect("infallible"));
    }
}

fn run_train(args: &ArgParser) -> Result<()> {
    if args.flag("stream") {
        return run_train_streaming(args);
    }
    let (train, valid, spec) = load_dataset(args)?;
    let mut params = learner_params_from_args(args)?;
    if let Some(spec) = &spec {
        apply_spec_defaults(&mut params, spec, args);
    }
    apply_csv_header_categoricals(&mut params, args)?;
    eprintln!(
        "training: {} rows x {} cols, objective={}, devices={}, threads={}, policy={}, compress={}",
        train.n_rows(),
        train.n_cols(),
        params.objective,
        params.n_devices,
        xgb_tpu::exec::ExecContext::new(params.threads).threads(),
        params.grow_policy,
        params.compress
    );

    // full cross-field validation before any work starts; every problem
    // in the flag/config set is reported at once
    let mut learner = Learner::from_params(params.clone())?;
    if let Some(path) = args.get("log-file") {
        learner.add_callback(Box::new(xgb_tpu::gbm::RecordLogger::new(path)));
    }
    let backend = args.get_str("backend", "native");
    let prior = load_resume_model(args)?;
    let booster = match backend.as_str() {
        "native" => match &prior {
            Some(p) => learner.resume(p, &train, valid.as_ref())?,
            None => learner.train(&train, valid.as_ref())?,
        },
        "xla" => {
            let artifacts = std::sync::Arc::new(Artifacts::discover()?);
            eprintln!("xla backend on platform {}", artifacts.platform());
            let be = Box::new(XlaHistBackend::new(artifacts));
            match &prior {
                Some(p) => learner.resume_with_backend(p, &train, valid.as_ref(), be)?,
                None => learner.train_with_backend(&train, valid.as_ref(), be)?,
            }
        }
        other => bail!("unknown backend {other:?} (native|xla)"),
    };
    let _ = NativeBackend::default(); // referenced for doc visibility

    report_booster(args, &booster, &params)
}

/// Out-of-core training: stream the input through the two-pass ingestion
/// pipeline instead of materializing it. The produced model is
/// bit-identical to the in-memory run over the same rows in the same
/// order (`--valid-frac 0`); there is no shuffled holdout in this mode.
fn run_train_streaming(args: &ArgParser) -> Result<()> {
    use xgb_tpu::data::{BatchSource, CsvSource, LibsvmSource, SyntheticSource};

    let mut params = learner_params_from_args(args)?;
    apply_csv_header_categoricals(&mut params, args)?;
    let seed: u64 = args.get_parse("seed", 42u64);
    let mut source: Box<dyn BatchSource> = if let Some(path) = args.get("csv") {
        Box::new(CsvSource::open(
            path,
            args.get_parse("label-col", 0usize),
            args.flag("header"),
            params.batch_rows,
        )?)
    } else if let Some(path) = args.get("libsvm") {
        Box::new(LibsvmSource::open(path, params.batch_rows)?)
    } else if let Some(name) = args.get("dataset") {
        let rows: usize = args.get_parse("rows", 20_000usize);
        let spec = DatasetSpec::by_name(name, rows)
            .with_context(|| format!("unknown dataset {name:?}; see `xgb-tpu datasets`"))?;
        apply_spec_defaults(&mut params, &spec, args);
        Box::new(SyntheticSource::new(&spec, seed, params.batch_rows))
    } else {
        bail!("streaming train needs --csv, --libsvm or --dataset")
    };

    eprintln!(
        "streaming training: source={}, batch_rows={}, objective={}, devices={}, threads={}",
        source.name(),
        params.batch_rows,
        params.objective,
        params.n_devices,
        xgb_tpu::exec::ExecContext::new(params.threads).threads(),
    );
    let mut learner = Learner::from_params(params.clone())?;
    if let Some(path) = args.get("log-file") {
        learner.add_callback(Box::new(xgb_tpu::gbm::RecordLogger::new(path)));
    }
    let backend = args.get_str("backend", "native");
    let prior = load_resume_model(args)?;
    let booster = match backend.as_str() {
        "native" => match &prior {
            Some(p) => learner.resume_from_source(p, source.as_mut(), None)?,
            None => learner.train_from_source(source.as_mut(), None)?,
        },
        "xla" => {
            let artifacts = std::sync::Arc::new(Artifacts::discover()?);
            eprintln!("xla backend on platform {}", artifacts.platform());
            let be = Box::new(XlaHistBackend::new(artifacts));
            match &prior {
                Some(p) => learner.resume_from_source_with_backend(p, source.as_mut(), None, be)?,
                None => learner.train_from_source_with_backend(source.as_mut(), None, be)?,
            }
        }
        other => bail!("unknown backend {other:?} (native|xla)"),
    };
    report_booster(args, &booster, &params)
}

/// `--resume <path>`: load the prior model to continue boosting from.
fn load_resume_model(args: &ArgParser) -> Result<Option<xgb_tpu::gbm::Booster>> {
    match args.get("resume") {
        Some(path) => {
            let prior = xgb_tpu::gbm::load_model_file(path)
                .with_context(|| format!("loading resume model {path}"))?;
            eprintln!(
                "resuming from {path}: {} rounds already boosted",
                prior.n_rounds()
            );
            Ok(Some(prior))
        }
        None => Ok(None),
    }
}

/// CSV-with-header convenience: columns whose header cell starts with
/// `cat:` are flagged categorical, unless `--categorical` was passed
/// explicitly (the flag wins).
fn apply_csv_header_categoricals(params: &mut LearnerParams, args: &ArgParser) -> Result<()> {
    if args.has("categorical") || !args.flag("header") {
        return Ok(());
    }
    let Some(path) = args.get("csv") else {
        return Ok(());
    };
    let cats =
        xgb_tpu::data::csv_header_categoricals(path, args.get_parse("label-col", 0usize))?;
    if !cats.is_empty() {
        eprintln!(
            "csv header flags categorical features: {}",
            cats.iter()
                .map(|f| format!("f{f}"))
                .collect::<Vec<_>>()
                .join(",")
        );
        params.categorical_features = cats;
    }
    Ok(())
}

fn report_booster(
    args: &ArgParser,
    booster: &xgb_tpu::gbm::Booster,
    params: &LearnerParams,
) -> Result<()> {
    let last = booster
        .eval_history
        .last()
        .context("no evaluation recorded")?;
    println!(
        "trained {} rounds in {:.2}s (simulated {:.3}s on {} devices)",
        booster.n_rounds(),
        booster.train_secs,
        booster.simulated_secs,
        params.n_devices
    );
    println!(
        "final: train-{m}={:.5}{}",
        last.train,
        last.valid
            .map(|v| format!(" valid-{m}={v:.5}", m = last.metric))
            .unwrap_or_default(),
        m = last.metric,
    );
    let s = &booster.build_stats;
    println!(
        "phases: hist={:.3}s partition={:.3}s split={:.3}s allreduce(host)={:.3}s \
         allreduce(simulated)={:.4}s comm={:.1} MB/device, {} hist rounds",
        s.hist_secs.iter().sum::<f64>(),
        s.partition_secs.iter().sum::<f64>(),
        s.split_secs,
        s.allreduce_host_secs,
        s.allreduce_sim_secs,
        s.comm_bytes_per_device as f64 / 1e6,
        s.hist_rounds
    );
    println!(
        "wall-clock (parallel engine): hist={:.3}s partition={:.3}s predict={:.3}s \
         (device compute total {:.3}s across {} devices)",
        s.hist_wall_secs,
        s.partition_wall_secs,
        s.predict_wall_secs,
        s.total_compute_secs(),
        params.n_devices
    );
    println!(
        "executor: wake={:.4}s arena_reused={:.2} MB allocs/round={:.1}",
        s.wake_wall_secs,
        s.arena_bytes_reused as f64 / 1e6,
        if s.hist_rounds == 0 {
            0.0
        } else {
            s.arena_allocs as f64 / s.hist_rounds as f64
        }
    );
    if s.pages_loaded > 0 {
        println!(
            "external memory: {} pages loaded, {:.3}s I/O ({:.3}s hidden by prefetch, \
             {:.3}s blocked), peak resident {:.2} MB/shard \
             (budget {} pages x {} rows/page)",
            s.pages_loaded,
            s.page_load_secs,
            s.prefetch_hidden_secs(),
            s.page_wait_secs,
            s.peak_resident_page_bytes as f64 / 1e6,
            params.max_resident_pages,
            params.page_rows
        );
    }

    // optional: persist the model
    if let Some(path) = args.get("model-out") {
        xgb_tpu::gbm::save_model_file(booster, path)?;
        println!("model saved to {path}");
    }
    // optional: feature importance report
    if args.has("importance") {
        let kind: xgb_tpu::gbm::ImportanceKind = args
            .get_str("importance", "gain")
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))?;
        println!("feature importance ({:?}):", kind);
        for (f, v) in xgb_tpu::gbm::feature_importance(booster, kind).iter().take(15) {
            println!("  f{f:<6} {v:.4}");
        }
    }
    Ok(())
}

/// Write a synthetic dataset's training split to CSV or LibSVM — the
/// fixture generator for the streaming-ingestion CI smoke.
fn run_export(args: &ArgParser) -> Result<()> {
    let name = args.get("dataset").context("--dataset required")?;
    let rows: usize = args.get_parse("rows", 20_000usize);
    let seed: u64 = args.get_parse("seed", 42u64);
    let out = args.get("out").context("--out required")?;
    let spec = DatasetSpec::by_name(name, rows)
        .with_context(|| format!("unknown dataset {name:?}; see `xgb-tpu datasets`"))?;
    let g = synthetic::generate(&spec, seed);
    match args.get_str("format", "libsvm").as_str() {
        "csv" => xgb_tpu::data::save_csv(&g.train, out)?,
        "libsvm" => xgb_tpu::data::save_libsvm(&g.train, out)?,
        other => bail!("unknown format {other:?} (csv|libsvm)"),
    }
    eprintln!(
        "wrote {} rows x {} cols of {} to {out}",
        g.train.n_rows(),
        g.train.n_cols(),
        spec.name
    );
    Ok(())
}

fn run_datasets() -> Result<()> {
    let mut t = Table::new(&["Name", "Paper rows", "Columns", "Task", "CLI name"]);
    for (spec, cli) in [
        (DatasetSpec::year_prediction_like(515_000), "yearprediction"),
        (DatasetSpec::synthetic_like(10_000_000), "synthetic"),
        (DatasetSpec::higgs_like(11_000_000), "higgs"),
        (DatasetSpec::covtype_like(581_000), "covtype"),
        (DatasetSpec::bosch_like(1_000_000), "bosch"),
        (DatasetSpec::airline_like(115_000_000), "airline"),
        (DatasetSpec::ranking_like(100_000), "ranking"),
    ] {
        t.add_row(vec![
            spec.name.to_string(),
            format!("{}", spec.rows),
            format!("{}", spec.cols),
            format!("{:?}", spec.task),
            cli.to_string(),
        ]);
    }
    println!("Table 1 registry (synthetic stand-ins; see DESIGN.md §2):\n");
    print!("{}", t.render());
    Ok(())
}

fn run_info(args: &ArgParser) -> Result<()> {
    let dir = xgb_tpu::runtime::find_artifact_dir(args.get("artifacts"))
        .context("artifacts not found; run `make artifacts`")?;
    println!("artifact dir: {}", dir.display());
    let artifacts = Artifacts::load(&dir)?;
    println!("PJRT platform: {}", artifacts.platform());
    let m = &artifacts.manifest;
    println!(
        "tiles: grad={} hist={}x{}x{} predict={}x{} trees={} nodes={}",
        m.grad_tile,
        m.hist_rows,
        m.hist_slots,
        m.hist_bins,
        m.predict_rows,
        m.predict_features,
        m.predict_trees,
        m.predict_nodes
    );
    Ok(())
}
