//! The multi-device tree builder: a faithful implementation of the paper's
//! Algorithm 1 plus the subtraction-trick optimisation, per-phase timing
//! and the simulated multi-GPU clock (DESIGN.md §5).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{ensure, Context as _, Result};

use crate::comm::{allreduce, AllReduceAlgo, CostModel, WireRing};
use crate::coordinator::device::{DeviceShard, HistBackend, NativeBackend, ShardStorage};
use crate::coordinator::CoordinatorParams;
use crate::compress::CompressedMatrixBuilder;
use crate::data::source::{
    scan_source_meta, scan_source_with_categories, BatchSource, DMatrixSource, IngestMeta,
    DEFAULT_BATCH_ROWS,
};
use crate::data::DMatrix;
use crate::exec::{BufferPool, ExecContext, ROW_CHUNK};
use crate::hist::{GradPairF64, Histogram};
use crate::quantile::{HistogramCuts, QuantizedMatrix};
use crate::tree::{ExpandEntry, GrowthPolicy, PolicyQueue, RegTree, SplitEvaluator};
use crate::{Float, GradPair};

/// Result of building one tree.
pub struct TreeBuildResult {
    pub tree: RegTree,
    /// Per-global-row margin delta (the new tree's leaf value for that
    /// row, already scaled by eta) — applied by the booster without
    /// re-traversing the tree.
    pub deltas: Vec<Float>,
    pub stats: BuildStats,
}

/// Per-tree timing/traffic statistics, the raw material of the Table 2 /
/// Figure 2 "gpu" rows.
///
/// Per-device seconds are measured **under the configured engine**: with
/// `threads > 1` the simulated devices run concurrently on shared host
/// cores (and fork chunk-parallel budgets), so `hist_secs` /
/// `partition_secs` — and therefore `simulated_secs`, which folds their
/// per-round max — reflect that contention. For the paper-faithful,
/// host-independent simulated clock, pin `threads = 1` as
/// `benches/fig2_scaling.rs` does for its device sweep.
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    /// Histogram-build seconds, per device (measured).
    pub hist_secs: Vec<f64>,
    /// Repartition seconds, per device (measured).
    pub partition_secs: Vec<f64>,
    /// Split-evaluation seconds (coordinator-side).
    pub split_secs: f64,
    /// Host seconds actually spent merging histograms.
    pub allreduce_host_secs: f64,
    /// Simulated collective seconds under the cost model.
    pub allreduce_sim_secs: f64,
    /// Bytes sent per device across all collectives.
    pub comm_bytes_per_device: usize,
    /// Number of histogram rounds (== number of expanded nodes + 1 root).
    pub hist_rounds: usize,
    /// Quantised cells visited by histogram builds (rows × row_stride),
    /// for throughput reporting.
    pub hist_cells: u64,
    /// Simulated multi-device wall-clock: Σ_round [max_d(compute_d) +
    /// comm_sim(round)].
    pub simulated_secs: f64,
    /// **Measured** wall-clock of the histogram device phase: elapsed time
    /// of each round's concurrent shard execution, summed over rounds.
    /// With `threads > 1` this drops below `Σ hist_secs`.
    pub hist_wall_secs: f64,
    /// **Measured** wall-clock of the repartition device phase.
    pub partition_wall_secs: f64,
    /// External-memory pages read back from spill files (all shards).
    pub pages_loaded: u64,
    /// Seconds spent reading + checksum-verifying pages (I/O work,
    /// largely hidden by prefetch — compare with `page_wait_secs`).
    pub page_load_secs: f64,
    /// Seconds the accumulators actually blocked waiting for a page.
    pub page_wait_secs: f64,
    /// Measured high-water mark of resident packed page bytes on any
    /// shard — the quantity the `max_resident_pages × page_bytes`
    /// contract bounds. Zero while fully resident.
    pub peak_resident_page_bytes: usize,
    /// **Measured** wall-clock of quantised prediction passes: the
    /// training loop's per-round validation scoring
    /// ([`crate::predict::quantised::accumulate_bin_tree_par`]) and
    /// [`MultiDeviceCoordinator::predict_margins`] /
    /// [`MultiDeviceCoordinator::predict_leaf_indices`] calls. Pages
    /// loaded *during prediction* land in [`pages_loaded`](Self::pages_loaded)
    /// via the same per-store round counters as training.
    pub predict_wall_secs: f64,
    /// **Measured** executor dispatch overhead: seconds spent submitting
    /// task batches and waiting for parked workers to wake (persistent
    /// engine), or spawning scoped threads (`XGB_SCOPED_EXEC=1`
    /// reference). The scoped-vs-persistent delta of this number is the
    /// per-round win the parked pool exists for.
    pub wake_wall_secs: f64,
    /// Bytes of pre-existing buffer capacity handed back out by the round
    /// arenas (histogram partials, decode scratch, flat all-reduce
    /// payloads, margin deltas) instead of being freshly allocated.
    pub arena_bytes_reused: u64,
    /// Fresh allocations the round arenas could **not** avoid (pool
    /// misses). ~0 per tree after the warm-up round is the steady-state
    /// target.
    pub arena_allocs: u64,
}

impl BuildStats {
    fn new(p: usize) -> Self {
        BuildStats {
            hist_secs: vec![0.0; p],
            partition_secs: vec![0.0; p],
            ..Default::default()
        }
    }

    /// Merge another tree's stats into an accumulated total.
    pub fn accumulate(&mut self, other: &BuildStats) {
        if self.hist_secs.len() < other.hist_secs.len() {
            self.hist_secs.resize(other.hist_secs.len(), 0.0);
            self.partition_secs.resize(other.partition_secs.len(), 0.0);
        }
        for (a, b) in self.hist_secs.iter_mut().zip(&other.hist_secs) {
            *a += b;
        }
        for (a, b) in self.partition_secs.iter_mut().zip(&other.partition_secs) {
            *a += b;
        }
        self.split_secs += other.split_secs;
        self.allreduce_host_secs += other.allreduce_host_secs;
        self.allreduce_sim_secs += other.allreduce_sim_secs;
        self.comm_bytes_per_device += other.comm_bytes_per_device;
        self.hist_rounds += other.hist_rounds;
        self.hist_cells += other.hist_cells;
        self.simulated_secs += other.simulated_secs;
        self.hist_wall_secs += other.hist_wall_secs;
        self.partition_wall_secs += other.partition_wall_secs;
        self.pages_loaded += other.pages_loaded;
        self.page_load_secs += other.page_load_secs;
        self.page_wait_secs += other.page_wait_secs;
        self.peak_resident_page_bytes = self
            .peak_resident_page_bytes
            .max(other.peak_resident_page_bytes);
        self.predict_wall_secs += other.predict_wall_secs;
        self.wake_wall_secs += other.wake_wall_secs;
        self.arena_bytes_reused += other.arena_bytes_reused;
        self.arena_allocs += other.arena_allocs;
    }

    /// Page-I/O seconds hidden by the async prefetch: the load work that
    /// ran while accumulation proceeded (total load time minus time the
    /// accumulator was actually blocked).
    pub fn prefetch_hidden_secs(&self) -> f64 {
        (self.page_load_secs - self.page_wait_secs).max(0.0)
    }

    /// Total measured device compute (sum over all devices — the work, not
    /// the wall-clock; concurrent execution makes wall < this).
    pub fn total_compute_secs(&self) -> f64 {
        self.hist_secs.iter().sum::<f64>()
            + self.partition_secs.iter().sum::<f64>()
            + self.split_secs
    }

    /// Measured wall-clock of the two thread-parallel device phases — the
    /// quantity the `threads` sweep in `benches/fig2_scaling.rs` reports.
    pub fn device_wall_secs(&self) -> f64 {
        self.hist_wall_secs + self.partition_wall_secs
    }
}

/// The Algorithm 1 coordinator over `p` simulated devices.
pub struct MultiDeviceCoordinator {
    pub params: CoordinatorParams,
    pub cuts: HistogramCuts,
    pub devices: Vec<DeviceShard>,
    backend: Box<dyn HistBackend>,
    evaluator: SplitEvaluator,
    n_rows: usize,
    /// Per-tree column-sampling stream (`colsample_bytree`).
    col_rng: crate::util::Pcg64,
    /// Thread budget for the real parallel engine (`params.threads`).
    exec: ExecContext,
    /// Round arenas owned by the coordinator: per-device histogram
    /// accumulators and merged/stored histograms recycle through
    /// `hist_pool`, flat all-reduce payloads through `flat_pool`, and
    /// per-tree margin deltas through `delta_pool` (closed by the
    /// booster via [`MultiDeviceCoordinator::recycle_deltas`]). After the
    /// warm-up tree, steady-state rounds draw everything from these
    /// pools — `BuildStats::arena_allocs` per tree goes to ~0.
    hist_pool: BufferPool<GradPairF64>,
    flat_pool: BufferPool<f64>,
    delta_pool: BufferPool<Float>,
    /// Established TCP ring when this process is one rank of a
    /// distributed run (`CoordinatorParams::dist`); `None` keeps every
    /// collective on the in-process simulation. Mutex because
    /// collectives take `&self` — they are strictly sequential (one per
    /// histogram round on the coordinator thread), so the lock is never
    /// contended.
    dist: Option<Mutex<WireRing>>,
}

impl MultiDeviceCoordinator {
    /// Shard `x` over `params.n_devices` devices through the streaming
    /// ingestion pipeline: sketch, quantise and optionally compress —
    /// an adapter over [`MultiDeviceCoordinator::from_source`] with an
    /// in-memory [`DMatrixSource`], so every construction path shares one
    /// implementation.
    pub fn from_dmatrix(x: &DMatrix, params: CoordinatorParams) -> Result<Self> {
        Self::with_backend(x, params, Box::new(NativeBackend::default()))
    }

    /// Same, with an explicit histogram backend (the XLA runtime path).
    pub fn with_backend(
        x: &DMatrix,
        params: CoordinatorParams,
        backend: Box<dyn HistBackend>,
    ) -> Result<Self> {
        let cuts = Self::distributed_cuts(x, &params)?;
        Self::with_cuts(x, params, cuts, backend)
    }

    /// **Streaming construction** (the out-of-core path): two passes over
    /// `src`. Pass 1 ([`scan_source`]) folds every batch into the
    /// per-column quantile sketch and collects labels/groups/row widths;
    /// pass 2 re-streams the source, quantises each batch against the
    /// frozen cuts and bit-packs it **directly into the owning device
    /// shard's pages** — the raw float matrix never materializes. The
    /// returned [`IngestMeta`] carries the labels (feature-less training
    /// substrate) and the measured peak transient bytes.
    ///
    /// Models built this way are bit-identical to the in-memory
    /// [`from_dmatrix`](Self::from_dmatrix) construction for every batch
    /// size and thread count (`rust/tests/streaming_ingest.rs`).
    pub fn from_source(
        src: &mut dyn BatchSource,
        params: CoordinatorParams,
    ) -> Result<(Self, IngestMeta)> {
        Self::from_source_with_backend(src, params, Box::new(NativeBackend::default()))
    }

    /// [`from_source`](Self::from_source) with an explicit histogram
    /// backend.
    pub fn from_source_with_backend(
        src: &mut dyn BatchSource,
        params: CoordinatorParams,
        backend: Box<dyn HistBackend>,
    ) -> Result<(Self, IngestMeta)> {
        let p = params.n_devices;
        ensure!(p >= 1, "need at least one device");
        let exec = ExecContext::new(params.threads);

        // pass 1: incremental sketch + O(n) metadata (flagged categorical
        // features get exact one-bin-per-category cuts instead)
        let (cuts, mut meta) =
            scan_source_with_categories(src, params.max_bins, &params.categorical, &exec)?;
        let n = meta.n_rows;
        ensure!(n >= p, "fewer rows ({n}) than devices ({p})");

        // pass 2: re-stream, quantise, pack straight into shard pages
        src.reset()?;
        let bounds: Vec<usize> = (0..=p).map(|d| d * n / p).collect();
        let strides = if meta.dense {
            vec![meta.n_cols; p]
        } else {
            shard_strides(&meta.row_nnz, &bounds)
        };
        let paging = PagingSpec::from_params(&params)?;
        let (devices, pass2_peak) = assemble_shards(
            src,
            &cuts,
            meta.col_shift,
            meta.n_cols,
            &bounds,
            &strides,
            meta.dense,
            params.compress,
            paging.as_ref(),
            &exec,
        )?;
        meta.peak_transient_bytes = meta.peak_batch_float_bytes.max(pass2_peak);
        Ok((Self::assembled(params, cuts, devices, n, backend, exec)?, meta))
    }

    /// Quantile cut generation over the streaming fold: one incremental
    /// per-column sketch fed in global row order
    /// ([`crate::quantile::StreamingSketch`]), chunk-parallel over
    /// columns. The push sequence per column depends only on the data —
    /// never on the batch size, device count or thread count — so the
    /// same dataset always quantises identically, whether it arrives from
    /// a file stream or an in-memory matrix.
    pub fn distributed_cuts(x: &DMatrix, params: &CoordinatorParams) -> Result<HistogramCuts> {
        let p = params.n_devices;
        ensure!(p >= 1, "need at least one device");
        let n = x.n_rows();
        ensure!(n >= p, "fewer rows ({n}) than devices ({p})");
        let exec = ExecContext::new(params.threads);
        let mut src = DMatrixSource::new(x, DEFAULT_BATCH_ROWS);
        let (cuts, _meta) =
            scan_source_with_categories(&mut src, params.max_bins, &params.categorical, &exec)?;
        Ok(cuts)
    }

    /// Construct with externally supplied cuts (shared across coordinators
    /// for cross-device-count determinism tests, or reused across boosting
    /// iterations). An adapter over the streaming pass-2 assembler with an
    /// in-memory source: shards are quantised and packed batch-wise, never
    /// materializing the full u32 bin matrix.
    pub fn with_cuts(
        x: &DMatrix,
        params: CoordinatorParams,
        cuts: HistogramCuts,
        backend: Box<dyn HistBackend>,
    ) -> Result<Self> {
        let p = params.n_devices;
        ensure!(p >= 1, "need at least one device");
        let n = x.n_rows();
        ensure!(n >= p, "fewer rows ({n}) than devices ({p})");
        let exec = ExecContext::new(params.threads);
        let bounds: Vec<usize> = (0..=p).map(|d| d * n / p).collect();
        let (dense, strides) = match x {
            DMatrix::Dense { .. } => (true, vec![x.n_cols(); p]),
            DMatrix::Csr { indptr, .. } => {
                let nnz: Vec<u32> = (0..n).map(|r| (indptr[r + 1] - indptr[r]) as u32).collect();
                (false, shard_strides(&nnz, &bounds))
            }
        };
        let paging = PagingSpec::from_params(&params)?;
        let mut src = DMatrixSource::new(x, DEFAULT_BATCH_ROWS);
        let (devices, _peak) = assemble_shards(
            &mut src,
            &cuts,
            0,
            x.n_cols(),
            &bounds,
            &strides,
            dense,
            params.compress,
            paging.as_ref(),
            &exec,
        )?;
        Self::assembled(params, cuts, devices, n, backend, exec)
    }

    /// **Resume construction**: stream a source against externally
    /// frozen cuts (the grid persisted in a serialized booster). Pass 1
    /// is the sketch-free [`scan_source_meta`] — resuming must *not*
    /// re-sketch, or the new stream would quantise on a different grid
    /// than the loaded trees' bin translation assumes; pass 2 is the
    /// ordinary shard assembler. The stream may present fewer trailing
    /// features than the frozen grid (they quantise as missing) but
    /// never more.
    pub fn from_source_with_cuts(
        src: &mut dyn BatchSource,
        params: CoordinatorParams,
        cuts: HistogramCuts,
        backend: Box<dyn HistBackend>,
    ) -> Result<(Self, IngestMeta)> {
        let p = params.n_devices;
        ensure!(p >= 1, "need at least one device");
        let exec = ExecContext::new(params.threads);
        let mut meta = scan_source_meta(src)?;
        ensure!(
            meta.n_cols <= cuts.n_features(),
            "stream has {} features but the frozen cuts cover {} — \
             resume data must match the training schema",
            meta.n_cols,
            cuts.n_features()
        );
        meta.n_cols = cuts.n_features();
        let n = meta.n_rows;
        ensure!(n >= p, "fewer rows ({n}) than devices ({p})");
        src.reset()?;
        let bounds: Vec<usize> = (0..=p).map(|d| d * n / p).collect();
        let strides = if meta.dense {
            vec![meta.n_cols; p]
        } else {
            shard_strides(&meta.row_nnz, &bounds)
        };
        let paging = PagingSpec::from_params(&params)?;
        let (devices, pass2_peak) = assemble_shards(
            src,
            &cuts,
            meta.col_shift,
            meta.n_cols,
            &bounds,
            &strides,
            meta.dense,
            params.compress,
            paging.as_ref(),
            &exec,
        )?;
        meta.peak_transient_bytes = meta.peak_batch_float_bytes.max(pass2_peak);
        Ok((Self::assembled(params, cuts, devices, n, backend, exec)?, meta))
    }

    /// Final assembly shared by every construction path. In distributed
    /// mode this is also where the TCP ring comes up: every rank runs
    /// the same deterministic ingest, so by construction all ranks hold
    /// identical cuts and shards when they meet here.
    fn assembled(
        params: CoordinatorParams,
        cuts: HistogramCuts,
        devices: Vec<DeviceShard>,
        n_rows: usize,
        backend: Box<dyn HistBackend>,
        exec: ExecContext,
    ) -> Result<Self> {
        let dist = match &params.dist {
            Some(cfg) => {
                ensure!(
                    cfg.peers.len() == params.n_devices,
                    "distributed runs need n_devices ({}) == number of peers ({}): \
                     rank r builds device r's partial and the wire ring supplies the rest",
                    params.n_devices,
                    cfg.peers.len()
                );
                ensure!(
                    params.allreduce == AllReduceAlgo::Ring,
                    "distributed mode implements the ring schedule only (got --allreduce {})",
                    params.allreduce
                );
                Some(Mutex::new(
                    WireRing::establish(cfg).context("assembling the distributed ring")?,
                ))
            }
            None => None,
        };
        let evaluator = SplitEvaluator::new(params.tree.clone());
        let col_rng = crate::util::Pcg64::new(params.seed ^ 0xc01_5a3f);
        Ok(MultiDeviceCoordinator {
            params,
            cuts,
            devices,
            backend,
            evaluator,
            n_rows,
            col_rng,
            exec,
            hist_pool: BufferPool::default(),
            flat_pool: BufferPool::default(),
            delta_pool: BufferPool::default(),
            dist,
        })
    }

    /// This process's rank when running distributed, else `None`.
    fn dist_rank(&self) -> Option<usize> {
        self.params.dist.as_ref().map(|d| d.rank)
    }

    /// Draw the per-tree feature mask (`None` when colsample is off).
    fn sample_columns(&mut self) -> Option<Vec<bool>> {
        let rate = self.params.colsample_bytree;
        if rate >= 1.0 {
            return None;
        }
        let n_feat = self.cuts.n_features();
        let k = ((n_feat as f64 * rate).ceil() as usize).clamp(1, n_feat);
        let chosen = self.col_rng.sample_indices(n_feat, k);
        let mut mask = vec![false; n_feat];
        for i in chosen {
            mask[i] = true;
        }
        Some(mask)
    }

    /// Fast-forward the per-tree column-sampling stream past `n_trees`
    /// already-built trees — resume's rng alignment: a continued run must
    /// draw the same masks for tree `k + i` as an uninterrupted run, so
    /// the stream consumes exactly what the skipped trees would have.
    /// No-op (matching `sample_columns`) while colsample is off.
    pub fn skip_column_samples(&mut self, n_trees: usize) {
        for _ in 0..n_trees {
            let _ = self.sample_columns();
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_bins(&self) -> usize {
        self.cuts.total_bins()
    }

    /// Feature-matrix bytes per device (paper's "600 MB/GPU"). For paged
    /// shards this is the spilled (on-disk) size — see
    /// [`device_resident_bytes`](Self::device_resident_bytes).
    pub fn device_bytes(&self) -> Vec<usize> {
        self.devices.iter().map(|d| d.storage.bytes()).collect()
    }

    /// Feature-matrix bytes currently held in host memory per device
    /// (equals [`device_bytes`](Self::device_bytes) while fully
    /// resident; live page handles only when spilled).
    pub fn device_resident_bytes(&self) -> Vec<usize> {
        self.devices
            .iter()
            .map(|d| d.storage.resident_bytes())
            .collect()
    }

    /// All-reduce a set of per-device f64 buffers; returns (merged copy,
    /// host seconds, simulated seconds, bytes/device). The non-merged
    /// buffers park in `flat_pool` for the next round instead of dropping.
    ///
    /// Distributed mode (`params.dist`): `bufs` holds exactly one buffer
    /// — the rank-local device's partial — and the TCP ring merges it
    /// against the other ranks'. The wire engine runs the identical
    /// chunk boundaries and f64 operand order as the simulation, so the
    /// merged buffer is bit-identical to what a single-process
    /// `n_devices == world` run computes. Simulated seconds are 0 there:
    /// the wire time is real and lands in `allreduce_host_secs`, and the
    /// byte figure is this rank's measured wire traffic (frame headers
    /// included, quantisation applied).
    fn collective(&self, mut bufs: Vec<Vec<f64>>) -> Result<(Vec<f64>, f64, f64, usize)> {
        if let Some(ring) = &self.dist {
            ensure!(
                bufs.len() == 1,
                "distributed collective expects only the rank-local partial, got {} buffers",
                bufs.len()
            );
            let mut buf = bufs.pop().expect("checked above");
            let host_t = Instant::now();
            let wire = ring
                .lock()
                .expect("wire ring lock poisoned")
                .allreduce(&mut buf)?;
            let host = host_t.elapsed().as_secs_f64();
            return Ok((buf, host, 0.0, wire.bytes_sent));
        }
        let host_t = Instant::now();
        let stats = allreduce(self.params.allreduce, &mut bufs);
        let host = host_t.elapsed().as_secs_f64();
        let sim = self.params.cost.time(&stats);
        let mut it = bufs.into_iter();
        let merged = it.next().unwrap();
        for spare in it {
            self.flat_pool.put(spare);
        }
        Ok((merged, host, sim, stats.bytes_per_device))
    }

    /// Build one tree from the global gradient vector — Algorithm 1.
    pub fn build_tree(&mut self, gradients: &[GradPair]) -> Result<TreeBuildResult> {
        ensure!(gradients.len() == self.n_rows, "gradient length mismatch");
        let p = self.devices.len();
        let mut stats = BuildStats::new(p);
        let eta = self.params.eta;
        let wake_before = self.exec.wake_wall_secs();

        // distribute gradients (every shard copies its slice concurrently)
        self.exec.parallel_map_mut(&mut self.devices, |_, d| {
            let lo = d.row_offset;
            let hi = lo + d.n_rows();
            d.begin_tree(&gradients[lo..hi]);
        });

        // root gradient sum: tiny collective over (g, h) pairs (each
        // device's sum is computed serially within the device, so the
        // value is independent of the thread count). Distributed: only
        // the rank-local device sums locally; the wire ring supplies the
        // other ranks' pairs.
        let sums: Vec<Vec<f64>> = if let Some(rank) = self.dist_rank() {
            let (g, h) = self.devices[rank].local_sum();
            vec![vec![g, h]]
        } else {
            self.exec.parallel_map(&self.devices, |_, d| {
                let (g, h) = d.local_sum();
                vec![g, h]
            })
        };
        let (root_vec, host, sim, bytes) = self.collective(sums)?;
        stats.allreduce_host_secs += host;
        stats.allreduce_sim_secs += sim;
        stats.comm_bytes_per_device += bytes;
        let root_sum = GradPairF64::new(root_vec[0], root_vec[1]);
        self.flat_pool.put(root_vec);

        let mut tree = RegTree::new_root(
            (eta * self.evaluator.leaf_weight(root_sum)) as Float,
            root_sum.hess as Float,
        );

        // root histogram round
        let mut hist_store: HashMap<usize, Histogram> = HashMap::new();
        let (root_hist, round_secs) = self.histogram_round(0, &mut stats)?;
        stats.simulated_secs += round_secs;
        hist_store.insert(0, root_hist);

        let feature_mask = self.sample_columns();
        let root_bounds = crate::tree::split::NodeBounds::default();
        let mut queue = PolicyQueue::new(self.params.policy);
        let split_t = Instant::now();
        if let Some(split) = self.evaluator.evaluate_bounded(
            hist_store.get(&0).unwrap(),
            &self.cuts,
            root_sum,
            feature_mask.as_deref(),
            root_bounds,
        ) {
            queue.push(ExpandEntry {
                nid: 0,
                depth: 0,
                split,
                node_sum: root_sum,
                bounds: root_bounds,
                timestamp: 0,
            });
        }
        stats.split_secs += split_t.elapsed().as_secs_f64();

        let max_depth = self.params.tree.max_depth;
        let max_leaves = self.params.tree.max_leaves;

        while let Some(entry) = queue.pop() {
            if max_leaves > 0 && tree.n_leaves() >= max_leaves {
                break;
            }
            let s = &entry.split;
            // materialise the split in the tree; leaf weights respect the
            // node's monotone bounds
            let left_value =
                (eta * self.evaluator.weight_clamped(s.left_sum, entry.bounds)) as Float;
            let right_value =
                (eta * self.evaluator.weight_clamped(s.right_sum, entry.bounds)) as Float;
            let (left_bounds, right_bounds) = self.evaluator.child_bounds(s, entry.bounds);
            let (left, right) = tree.apply_split(
                entry.nid,
                s.feature,
                s.threshold,
                s.default_left,
                s.gain as Float,
                left_value,
                s.left_sum.hess as Float,
                right_value,
                s.right_sum.hess as Float,
            );
            if s.is_categorical() {
                // membership split: stamp the category set on the node
                // (threshold stays 0.0 — routing is by the bitset)
                tree.set_categories(entry.nid, s.categories);
            }

            // RepartitionInstances on every device — all shards
            // concurrently on the pool (repartitioning never touches the
            // histogram backend, so it parallelises regardless of
            // backend), each shard chunk-parallel under its forked budget
            let cuts = self.cuts.clone();
            let dev_exec = self.exec.fork(p);
            let part_wall = Instant::now();
            let part_results: Vec<(usize, usize, f64)> =
                self.exec.parallel_map_mut(&mut self.devices, |_, dev| {
                    let t = Instant::now();
                    let (nl, nr) =
                        dev.repartition(entry.nid, s, left, right, &cuts, &dev_exec);
                    (nl, nr, t.elapsed().as_secs_f64())
                });
            stats.partition_wall_secs += part_wall.elapsed().as_secs_f64();
            let mut n_left_total = 0usize;
            let mut n_right_total = 0usize;
            let mut part_secs = vec![0.0f64; p];
            for (di, &(nl, nr, secs)) in part_results.iter().enumerate() {
                part_secs[di] = secs;
                stats.partition_secs[di] += secs;
                n_left_total += nl;
                n_right_total += nr;
            }

            // children at depth+1; can they be expanded further?
            let child_depth = entry.depth + 1;
            let depth_ok = max_depth == 0 || child_depth < max_depth;

            if !depth_ok {
                if let Some(h) = hist_store.remove(&entry.nid) {
                    self.hist_pool.put(h.bins);
                }
                continue;
            }

            // BuildPartialHistograms for the smaller child + AllReduce;
            // sibling via subtraction from the parent histogram.
            let (small_nid, _large_nid) = if n_left_total <= n_right_total {
                (left, right)
            } else {
                (right, left)
            };
            let (small_hist, mut round_secs) = self.histogram_round(small_nid, &mut stats)?;
            // repartition happens within the same device round as the
            // histogram build: add the slowest device's partition time
            round_secs += part_secs.iter().cloned().fold(0.0, f64::max);
            stats.simulated_secs += round_secs;

            let mut parent_hist = hist_store
                .remove(&entry.nid)
                .expect("parent histogram must exist");
            let large_hist = if self.params.subtraction {
                // subtraction trick, in place: the parent's buffer becomes
                // the sibling. Elementwise `parent − small`, the exact
                // expression of [`crate::hist::subtract`], so the result
                // is bit-identical — minus the allocation.
                for (pb, sb) in parent_hist.bins.iter_mut().zip(small_hist.bins.iter()) {
                    *pb = *pb - *sb;
                }
                parent_hist
            } else {
                // A3 ablation: build the larger sibling from its rows too
                self.hist_pool.put(parent_hist.bins);
                let (h, extra) = self.histogram_round(_large_nid, &mut stats)?;
                stats.simulated_secs += extra;
                h
            };

            // EvaluateSplit for both children; queue feasible expansions
            let split_t = Instant::now();
            let (left_hist, right_hist) = if small_nid == left {
                (&small_hist, &large_hist)
            } else {
                (&large_hist, &small_hist)
            };
            let left_split = self.evaluator.evaluate_bounded(
                left_hist,
                &self.cuts,
                s.left_sum,
                feature_mask.as_deref(),
                left_bounds,
            );
            let right_split = self.evaluator.evaluate_bounded(
                right_hist,
                &self.cuts,
                s.right_sum,
                feature_mask.as_deref(),
                right_bounds,
            );
            stats.split_secs += split_t.elapsed().as_secs_f64();

            if let Some(ls) = left_split {
                queue.push(ExpandEntry {
                    nid: left,
                    depth: child_depth,
                    split: ls,
                    node_sum: s.left_sum,
                    bounds: left_bounds,
                    timestamp: 0,
                });
                if !hist_store.contains_key(&left) {
                    // stored copies come from the pool too
                    let mut bins = self.hist_pool.take(left_hist.bins.len());
                    bins.copy_from_slice(&left_hist.bins);
                    hist_store.insert(left, Histogram { bins });
                }
            }
            if let Some(rs) = right_split {
                queue.push(ExpandEntry {
                    nid: right,
                    depth: child_depth,
                    split: rs,
                    node_sum: s.right_sum,
                    bounds: right_bounds,
                    timestamp: 0,
                });
                if !hist_store.contains_key(&right) {
                    let mut bins = self.hist_pool.take(right_hist.bins.len());
                    bins.copy_from_slice(&right_hist.bins);
                    hist_store.insert(right, Histogram { bins });
                }
            }
            self.hist_pool.put(small_hist.bins);
            self.hist_pool.put(large_hist.bins);
        }

        // unexpanded node histograms return to the pool for the next tree
        for (_, h) in hist_store.drain() {
            self.hist_pool.put(h.bins);
        }

        // margin deltas from final leaf assignment — no tree re-traversal.
        // The buffer comes from the delta arena (cleared to 0.0); the
        // booster hands it back via `recycle_deltas` after applying it.
        let mut deltas = self.delta_pool.take(self.n_rows);
        for dev in &self.devices {
            for (nid, rows) in dev.partitioner.leaf_of_rows() {
                let v = tree.nodes[nid].leaf_value;
                for &r in rows {
                    deltas[dev.row_offset + r as usize] = v;
                }
            }
        }

        // drain this tree's paging counters from every spilled shard
        self.drain_page_stats(&mut stats);

        // executor + arena accounting for this tree: wake/submit seconds
        // accrued on the (shared, forked) engine, and the hit/miss
        // counters of every round arena that fed the tree
        stats.wake_wall_secs = self.exec.wake_wall_secs() - wake_before;
        let mut arena = self.backend.drain_arena_stats();
        arena.merge(self.hist_pool.drain_stats());
        arena.merge(self.flat_pool.drain_stats());
        arena.merge(self.delta_pool.drain_stats());
        stats.arena_allocs = arena.misses;
        stats.arena_bytes_reused = arena.bytes_reused;

        Ok(TreeBuildResult {
            tree,
            deltas,
            stats,
        })
    }

    /// One histogram round for node `nid`: partial build on every device
    /// (measured), then the all-reduce merge. With a thread-safe backend
    /// (`HistBackend::as_parallel`) the shards run **concurrently** on the
    /// pool, each with a forked chunk-parallel budget; a pinned backend
    /// (the Rc-based XLA runtime) keeps the serial device loop on this
    /// thread. Partials enter the collective in device order either way,
    /// so the merged histogram is identical. Returns the merged histogram
    /// and this round's simulated wall-clock contribution
    /// `max_d(build_d) + comm`.
    fn histogram_round(
        &mut self,
        nid: usize,
        stats: &mut BuildStats,
    ) -> Result<(Histogram, f64)> {
        let n_bins = self.cuts.total_bins();
        let p = self.devices.len();
        let wall_t = Instant::now();
        // per-device (flat partial, build seconds, cells visited) — both
        // the per-device accumulator and its flat all-reduce payload come
        // from the coordinator's round arenas (the pools are internally
        // synchronised, so concurrent shards take/put freely)
        let hist_pool = &self.hist_pool;
        let flat_pool = &self.flat_pool;
        let flatten = |h: Histogram| -> Vec<f64> {
            let mut flat = flat_pool.take(h.bins.len() * 2);
            for (i, b) in h.bins.iter().enumerate() {
                flat[2 * i] = b.grad;
                flat[2 * i + 1] = b.hess;
            }
            hist_pool.put(h.bins);
            flat
        };
        // distributed: this process builds only its own rank's shard —
        // the wire collective supplies every other rank's partial. The
        // single local shard takes the pinned path with the full
        // chunk-parallel budget (bit-identical across thread counts).
        let local: Vec<usize> = match self.dist_rank() {
            Some(r) => vec![r],
            None => (0..p).collect(),
        };
        let use_pool =
            self.dist.is_none() && self.exec.threads() > 1 && self.backend.as_parallel().is_some();
        let results: Vec<Result<(Vec<f64>, f64, u64)>> = if use_pool {
            let pb = self.backend.as_parallel().expect("checked above");
            let dev_exec = self.exec.fork(p);
            self.exec.parallel_map(&self.devices, |_, dev| {
                let rows = dev.partitioner.node_rows(nid);
                let mut h = Histogram {
                    bins: hist_pool.take(n_bins),
                };
                let t = Instant::now();
                pb.build_histogram_shard(dev, rows, &mut h, &dev_exec)?;
                let secs = t.elapsed().as_secs_f64();
                let cells = (rows.len() * dev.storage.row_stride()) as u64;
                Ok((flatten(h), secs, cells))
            })
        } else {
            // pinned executor path: the backend owns thread-bound state
            // (or threads = 1), so every shard executes on this thread
            let devices = &self.devices;
            let backend = &mut self.backend;
            let exec = self.exec.clone();
            local
                .iter()
                .map(|&di| {
                    let dev = &devices[di];
                    let rows = dev.partitioner.node_rows(nid);
                    let mut h = Histogram {
                        bins: hist_pool.take(n_bins),
                    };
                    let t = Instant::now();
                    backend.build_histogram(dev, rows, &mut h, &exec)?;
                    let secs = t.elapsed().as_secs_f64();
                    let cells = (rows.len() * dev.storage.row_stride()) as u64;
                    Ok((flatten(h), secs, cells))
                })
                .collect()
        };
        stats.hist_wall_secs += wall_t.elapsed().as_secs_f64();

        let mut partials: Vec<Vec<f64>> = Vec::with_capacity(p);
        let mut max_build = 0.0f64;
        for (i, r) in results.into_iter().enumerate() {
            let di = local[i];
            let (flat, secs, cells) = r?;
            stats.hist_secs[di] += secs;
            stats.hist_cells += cells;
            max_build = max_build.max(secs);
            partials.push(flat);
        }
        let (merged, host, sim, bytes) = self.collective(partials)?;
        stats.allreduce_host_secs += host;
        stats.allreduce_sim_secs += sim;
        stats.comm_bytes_per_device += bytes;
        stats.hist_rounds += 1;
        // merged histogram draws from the pool too; the flat payload parks
        let mut bins = self.hist_pool.take(n_bins);
        for (b, c) in bins.iter_mut().zip(merged.chunks_exact(2)) {
            *b = GradPairF64::new(c[0], c[1]);
        }
        self.flat_pool.put(merged);
        Ok((Histogram { bins }, max_build + sim))
    }

    /// Hand a spent per-tree delta buffer back to the round arena — the
    /// booster calls this after folding [`TreeBuildResult::deltas`] into
    /// its margin cache, closing the zero-allocation loop.
    pub fn recycle_deltas(&self, deltas: Vec<Float>) {
        self.delta_pool.put(deltas);
    }

    /// **Compressed end-to-end prediction** (§2.4 from the §2.2
    /// representation): raw margins for a forest grouped by output,
    /// computed straight from the quantised shard storage — the float
    /// matrix is never touched. Trees are translated once into
    /// bin-threshold form against this coordinator's cuts
    /// ([`crate::predict::quantised::BinForest`]); shards score
    /// concurrently on the exec pool (chunk-parallel within each
    /// resident shard under a forked budget), and a
    /// [`ShardStorage::Paged`] shard streams its pages back through the
    /// same prefetch pipeline and `max_resident_pages` budget as a
    /// histogram round. Results are **bit-identical** to
    /// [`crate::predict::predict_margins_par`] on the raw values at
    /// every page size, budget, thread count and device count
    /// (`rust/tests/compressed_predict.rs`).
    ///
    /// Returns the margins plus a [`BuildStats`] carrying
    /// `predict_wall_secs` and any pages loaded during the pass.
    pub fn predict_margins(
        &self,
        trees: &[Vec<RegTree>],
        base_score: &[Float],
    ) -> Result<(Vec<Vec<Float>>, BuildStats)> {
        ensure!(
            trees.len() == base_score.len(),
            "tree groups ({}) != base scores ({})",
            trees.len(),
            base_score.len()
        );
        let p = self.devices.len();
        let mut stats = BuildStats::new(p);
        let wall = Instant::now();
        let wake_before = self.exec.wake_wall_secs();
        let forest = crate::predict::quantised::BinForest::from_trees(trees, &self.cuts);
        let dev_exec = self.exec.fork(p);
        let shard_margins: Vec<Result<Vec<Vec<Float>>>> =
            self.exec.parallel_map(&self.devices, |_, dev| {
                use crate::predict::quantised as q;
                match &dev.storage {
                    ShardStorage::Quantized(qm) => Ok(q::predict_margins_quantized(
                        &forest, base_score, qm, &self.cuts, &dev_exec,
                    )),
                    ShardStorage::Compressed(cm) => Ok(q::predict_margins_compressed(
                        &forest, base_score, cm, &self.cuts, &dev_exec,
                    )),
                    ShardStorage::Paged(ps) => {
                        q::predict_margins_paged(&forest, base_score, ps, &self.cuts, &dev_exec)
                    }
                }
            });
        let mut out: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; self.n_rows]).collect();
        for (dev, sm) in self.devices.iter().zip(shard_margins) {
            let sm = sm?;
            for (k, m) in sm.into_iter().enumerate() {
                out[k][dev.row_offset..dev.row_offset + dev.n_rows()].copy_from_slice(&m);
            }
        }
        stats.predict_wall_secs = wall.elapsed().as_secs_f64();
        stats.wake_wall_secs = self.exec.wake_wall_secs() - wake_before;
        self.drain_page_stats(&mut stats);
        Ok((out, stats))
    }

    /// Leaf indices for one output group's trees, straight from the
    /// quantised shard storage — bit-identical to
    /// [`crate::predict::predict_leaf_indices_par`] on the raw values.
    pub fn predict_leaf_indices(
        &self,
        trees: &[RegTree],
    ) -> Result<(Vec<Vec<u32>>, BuildStats)> {
        let p = self.devices.len();
        let mut stats = BuildStats::new(p);
        let wall = Instant::now();
        let bin_trees: Vec<crate::predict::quantised::BinTree> = trees
            .iter()
            .map(|t| crate::predict::quantised::BinTree::from_tree(t, &self.cuts))
            .collect();
        let dev_exec = self.exec.fork(p);
        let shard_leaves: Vec<Result<Vec<Vec<u32>>>> =
            self.exec.parallel_map(&self.devices, |_, dev| {
                use crate::predict::quantised as q;
                match &dev.storage {
                    ShardStorage::Quantized(qm) => {
                        Ok(q::leaf_indices_quantized(&bin_trees, qm, &self.cuts, &dev_exec))
                    }
                    ShardStorage::Compressed(cm) => {
                        Ok(q::leaf_indices_compressed(&bin_trees, cm, &self.cuts, &dev_exec))
                    }
                    ShardStorage::Paged(ps) => {
                        q::leaf_indices_paged(&bin_trees, ps, &self.cuts, &dev_exec)
                    }
                }
            });
        let mut out: Vec<Vec<u32>> = trees.iter().map(|_| vec![0u32; self.n_rows]).collect();
        for (dev, sl) in self.devices.iter().zip(shard_leaves) {
            let sl = sl?;
            for (t, leaves) in sl.into_iter().enumerate() {
                out[t][dev.row_offset..dev.row_offset + dev.n_rows()].copy_from_slice(&leaves);
            }
        }
        stats.predict_wall_secs = wall.elapsed().as_secs_f64();
        self.drain_page_stats(&mut stats);
        Ok((out, stats))
    }

    /// Fold every paged shard's round counters (pages loaded, I/O and
    /// wait seconds, measured residency peak) into `stats`.
    fn drain_page_stats(&self, stats: &mut BuildStats) {
        for dev in &self.devices {
            if let ShardStorage::Paged(ps) = &dev.storage {
                let s = ps.take_round_stats();
                stats.pages_loaded += s.pages_loaded;
                stats.page_load_secs += s.load_secs;
                stats.page_wait_secs += s.wait_secs;
                stats.peak_resident_page_bytes =
                    stats.peak_resident_page_bytes.max(s.peak_resident_bytes);
            }
        }
    }
}

/// Per-shard ELLPACK strides for a sparse stream: the maximum present
/// count of any row inside each shard's contiguous range (min 1, matching
/// the quantizer's degenerate-row rule).
fn shard_strides(row_nnz: &[u32], bounds: &[usize]) -> Vec<usize> {
    bounds
        .windows(2)
        .map(|w| {
            row_nnz[w[0]..w[1]]
                .iter()
                .copied()
                .max()
                .unwrap_or(0)
                .max(1) as usize
        })
        .collect()
}

/// How pass 2 should spill packed pages to disk (None = fully resident).
#[derive(Debug)]
pub(crate) struct PagingSpec {
    pub page_rows: usize,
    pub max_resident_pages: usize,
    /// Per-coordinator temp dir holding one page file per shard; removed
    /// with the last shard's `PageStore`.
    pub dir: std::path::PathBuf,
}

impl Drop for PagingSpec {
    /// Sweep the spill dir if construction failed before any shard's
    /// page file landed in it (an occupied dir makes `remove_dir` fail,
    /// which is the success case — the page stores own cleanup then).
    fn drop(&mut self) {
        let _ = std::fs::remove_dir(&self.dir);
    }
}

impl PagingSpec {
    /// Build the spill spec for these params, creating the temp dir
    /// (`None` while fully resident). Paging packs pages by definition,
    /// so it requires the compressed storage form.
    fn from_params(params: &CoordinatorParams) -> Result<Option<Self>> {
        if params.max_resident_pages == 0 {
            return Ok(None);
        }
        ensure!(
            params.compress,
            "max_resident_pages > 0 requires compress = true (pages are bit-packed)"
        );
        ensure!(params.page_rows >= 1, "page_rows must be >= 1");
        static SPILL_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        // the prefix marks the dir as spill-owned, so the page stores may
        // remove it once the last shard's file is gone
        let dir = std::env::temp_dir().join(format!(
            "{}{}_{}",
            crate::compress::page::SPILL_DIR_PREFIX,
            std::process::id(),
            SPILL_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        Ok(Some(PagingSpec {
            page_rows: params.page_rows,
            max_resident_pages: params.max_resident_pages,
            dir,
        }))
    }
}

/// Incremental shard storage: rows append in global order, padded to the
/// shard's ELLPACK stride — raw u32 bins, bit-packed words, or bit-packed
/// pages spilled straight to the shard's on-disk page file.
enum ShardBuilder {
    Quantized {
        bins: Vec<u32>,
        n_rows: usize,
        n_features: usize,
        row_stride: usize,
        n_bins: usize,
        dense: bool,
    },
    Compressed(CompressedMatrixBuilder),
    Paged(crate::compress::page::PagedMatrixBuilder),
}

impl ShardBuilder {
    #[allow(clippy::too_many_arguments)]
    fn new(
        shard_id: usize,
        n_rows: usize,
        n_features: usize,
        row_stride: usize,
        n_bins: usize,
        dense: bool,
        compress: bool,
        paging: Option<&PagingSpec>,
    ) -> Result<Self> {
        if let Some(p) = paging {
            return Ok(ShardBuilder::Paged(
                crate::compress::page::PagedMatrixBuilder::new(
                    p.dir.join(format!("shard{shard_id}.pages")),
                    n_rows,
                    n_features,
                    row_stride,
                    n_bins,
                    dense,
                    p.page_rows,
                    p.max_resident_pages,
                )?,
            ));
        }
        Ok(if compress {
            ShardBuilder::Compressed(CompressedMatrixBuilder::new(
                n_rows, n_features, row_stride, n_bins, dense,
            ))
        } else {
            ShardBuilder::Quantized {
                bins: Vec::with_capacity(n_rows * row_stride),
                n_rows,
                n_features,
                row_stride,
                n_bins,
                dense,
            }
        })
    }

    fn push_row(&mut self, symbols: &[u32]) -> Result<()> {
        match self {
            ShardBuilder::Quantized {
                bins,
                row_stride,
                n_bins,
                ..
            } => {
                // hard check: a pass-2 row wider than the pass-1 stride
                // (replay-contract violation) must fail loudly, not wrap
                // the resize length and silently corrupt the shard
                assert!(
                    symbols.len() <= *row_stride,
                    "row has {} symbols but stride is {}",
                    symbols.len(),
                    *row_stride
                );
                bins.extend_from_slice(symbols);
                bins.resize(bins.len() + (*row_stride - symbols.len()), *n_bins as u32);
                Ok(())
            }
            ShardBuilder::Compressed(b) => {
                b.push_row(symbols);
                Ok(())
            }
            ShardBuilder::Paged(b) => b.push_row(symbols),
        }
    }

    fn finish(self) -> Result<ShardStorage> {
        Ok(match self {
            ShardBuilder::Quantized {
                bins,
                n_rows,
                n_features,
                row_stride,
                n_bins,
                dense,
            } => {
                debug_assert_eq!(bins.len(), n_rows * row_stride);
                ShardStorage::Quantized(QuantizedMatrix {
                    bins,
                    n_rows,
                    n_features,
                    row_stride,
                    n_bins,
                    dense,
                })
            }
            ShardBuilder::Compressed(b) => ShardStorage::Compressed(b.finish()),
            ShardBuilder::Paged(b) => ShardStorage::Paged(b.finish()?),
        })
    }
}

/// **Pass 2** of the streaming pipeline: re-stream the source, quantise
/// each batch against the frozen cuts (chunk-parallel; chunk boundaries
/// depend only on the batch size, so results are thread-count-invariant)
/// and append every row to its owning device shard — into RAM, or, with
/// a `paging` spec, straight into the shard's on-disk spill writer so
/// the packed pages never fully materialize in memory either. Returns
/// the shards plus the peak transient bytes of this pass (batch floats +
/// symbol scratch — the quantities the O(`batch_rows × n_cols`) contract
/// bounds).
#[allow(clippy::too_many_arguments)]
fn assemble_shards(
    src: &mut dyn BatchSource,
    cuts: &HistogramCuts,
    col_shift: u32,
    n_cols: usize,
    bounds: &[usize],
    strides: &[usize],
    dense: bool,
    compress: bool,
    paging: Option<&PagingSpec>,
    exec: &ExecContext,
) -> Result<(Vec<DeviceShard>, usize)> {
    let p = strides.len();
    let n_bins = cuts.total_bins();
    let null = n_bins as u32;
    let shift = col_shift as usize;
    let total = *bounds.last().unwrap();
    let mut builders: Vec<ShardBuilder> = (0..p)
        .map(|d| {
            ShardBuilder::new(
                d,
                bounds[d + 1] - bounds[d],
                n_cols,
                strides[d],
                n_bins,
                dense,
                compress,
                paging,
            )
        })
        .collect::<Result<_>>()?;

    let mut next_row = 0usize;
    let mut dev = 0usize;
    let mut peak = 0usize;
    while let Some(batch) = src.next_batch()? {
        let b_rows = batch.n_rows();
        ensure!(
            next_row + b_rows <= total,
            "pass 2 replay yielded more rows than pass 1 saw"
        );
        // quantise the batch into one flat symbol buffer + per-row counts
        // per chunk (dense rows carry the full positional stride incl.
        // nulls; sparse rows are packed and padded by the shard builder).
        // A flat buffer, not a Vec per row: pass 2 is the out-of-core
        // ingest hot loop and must not heap-allocate per dataset row.
        let sym_chunks: Vec<(Vec<u32>, Vec<u32>)> =
            exec.map_chunks(b_rows, ROW_CHUNK, |_, range| {
                let mut flat: Vec<u32> = Vec::with_capacity(range.len() * n_cols.max(4));
                let mut lens: Vec<u32> = Vec::with_capacity(range.len());
                for i in range {
                    let start = flat.len();
                    if dense {
                        flat.resize(start + n_cols, null);
                        for (f, v) in batch.x.iter_row(i) {
                            flat[start + f] = cuts.bin_index(f, v);
                        }
                    } else {
                        for (c, v) in batch.x.iter_row(i) {
                            flat.push(cuts.bin_index(c - shift, v));
                        }
                    }
                    lens.push((flat.len() - start) as u32);
                }
                (flat, lens)
            });
        let sym_bytes: usize = sym_chunks
            .iter()
            .map(|(flat, lens)| (flat.len() + lens.len()) * std::mem::size_of::<u32>())
            .sum();
        peak = peak.max(batch.x.float_bytes() + sym_bytes);
        for (flat, lens) in &sym_chunks {
            let mut off = 0usize;
            for &len in lens {
                let row_syms = &flat[off..off + len as usize];
                off += len as usize;
                while next_row >= bounds[dev + 1] {
                    dev += 1;
                }
                builders[dev].push_row(row_syms)?;
                next_row += 1;
            }
        }
    }
    ensure!(
        next_row == total,
        "pass 2 replay yielded {next_row} rows, pass 1 saw {total}"
    );
    let devices: Vec<DeviceShard> = builders
        .into_iter()
        .enumerate()
        .map(|(d, b)| Ok(DeviceShard::new(d, bounds[d], b.finish()?)))
        .collect::<Result<_>>()?;
    Ok((devices, peak))
}

/// Convenience: cost-model-only scaling projection. Given measured
/// single-device per-round compute and histogram size, project the
/// simulated wall-clock for `p` devices (used by the Figure 2 bench for
/// the analytic overlay; the measured path re-runs the coordinator).
pub fn project_scaling(
    single_device_compute_secs: f64,
    hist_elems: usize,
    rounds: usize,
    p: usize,
    cost: &CostModel,
) -> f64 {
    let per_device = single_device_compute_secs / p as f64;
    per_device + rounds as f64 * cost.ring_time(p, hist_elems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::tree::TreeParams;

    fn simple_params(p: usize) -> CoordinatorParams {
        CoordinatorParams {
            n_devices: p,
            compress: false,
            tree: TreeParams {
                max_depth: 3,
                ..Default::default()
            },
            max_bins: 16,
            ..Default::default()
        }
    }

    fn logistic_grads(ds: &crate::data::Dataset, margins: &[Float]) -> Vec<GradPair> {
        ds.y
            .iter()
            .zip(margins.iter())
            .map(|(&y, &m)| {
                let pr = 1.0 / (1.0 + (-m).exp());
                GradPair::new(pr - y, (pr * (1.0 - pr)).max(1e-6))
            })
            .collect()
    }

    #[test]
    fn single_device_builds_reasonable_tree() {
        let g = generate(&DatasetSpec::higgs_like(2000), 1);
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, simple_params(1)).unwrap();
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let r = c.build_tree(&grads).unwrap();
        assert!(r.tree.n_leaves() >= 2, "tree should split");
        assert!(r.tree.max_depth() <= 3);
        assert_eq!(r.deltas.len(), g.train.n_rows());
        // deltas reduce the logistic loss direction: correlation with -grad
        let mut corr = 0.0f64;
        for (d, gp) in r.deltas.iter().zip(grads.iter()) {
            corr += (*d as f64) * (-gp.grad as f64);
        }
        assert!(corr > 0.0, "tree should move against the gradient");
    }

    #[test]
    fn multi_device_equals_single_device() {
        let g = generate(&DatasetSpec::higgs_like(3000), 7);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        // shared cuts isolate the invariant: same quantisation => identical
        // tree regardless of device count. (Since the streaming-ingestion
        // refactor the cuts themselves are device-count-invariant too —
        // the sketch folds in global row order — so sharing is belt and
        // braces here.)
        let cuts = MultiDeviceCoordinator::distributed_cuts(&g.train.x, &simple_params(1))
            .unwrap();
        let mut trees = Vec::new();
        for p in [1usize, 2, 4, 8] {
            let mut c = MultiDeviceCoordinator::with_cuts(
                &g.train.x,
                simple_params(p),
                cuts.clone(),
                Box::new(NativeBackend::default()),
            )
            .unwrap();
            let r = c.build_tree(&grads).unwrap();
            trees.push((p, r.tree));
        }
        let (_, ref t1) = trees[0];
        for (p, t) in &trees[1..] {
            assert_eq!(t.n_nodes(), t1.n_nodes(), "p={p} node count");
            for (a, b) in t.nodes.iter().zip(t1.nodes.iter()) {
                assert_eq!(a.feature, b.feature, "p={p}");
                assert_eq!(a.left, b.left, "p={p}");
                assert!((a.threshold - b.threshold).abs() < 1e-6, "p={p}");
                assert!((a.leaf_value - b.leaf_value).abs() < 1e-5, "p={p}");
            }
        }
    }

    #[test]
    fn compressed_equals_uncompressed() {
        let g = generate(&DatasetSpec::higgs_like(2000), 3);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let mut pc = simple_params(2);
        pc.compress = true;
        let mut pu = simple_params(2);
        pu.compress = false;
        let mut cc = MultiDeviceCoordinator::from_dmatrix(&g.train.x, pc).unwrap();
        let mut cu = MultiDeviceCoordinator::from_dmatrix(&g.train.x, pu).unwrap();
        let rc = cc.build_tree(&grads).unwrap();
        let ru = cu.build_tree(&grads).unwrap();
        assert_eq!(rc.tree, ru.tree);
        assert_eq!(rc.deltas, ru.deltas);
    }

    #[test]
    fn deltas_match_tree_predictions() {
        let g = generate(&DatasetSpec::year_prediction_like(1500), 5);
        let mut params = simple_params(2);
        params.eta = 0.5;
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params).unwrap();
        // squared-error gradients around mean
        let mean: f32 = g.train.y.iter().sum::<f32>() / g.train.y.len() as f32;
        let grads: Vec<GradPair> = g
            .train
            .y
            .iter()
            .map(|&y| GradPair::new(mean - y, 1.0))
            .collect();
        let r = c.build_tree(&grads).unwrap();
        // NOTE: deltas come from the quantised routing; tree.predict_row
        // uses raw values with the recovered thresholds — they must agree.
        for row in 0..g.train.n_rows() {
            let pred = r.tree.predict_row(&g.train.x, row);
            assert!(
                (pred - r.deltas[row]).abs() < 1e-6,
                "row {row}: {pred} vs {}",
                r.deltas[row]
            );
        }
    }

    #[test]
    fn categorical_split_trains_and_routes_consistently() {
        // the target depends on *membership* of f0 in {0, 5} — no single
        // threshold separates it, a membership split does in one node
        let n = 400;
        let cats = [0.0f32, 1.0, 3.0, 5.0, 7.0];
        let mut vals = Vec::with_capacity(n * 2);
        let mut y: Vec<Float> = Vec::with_capacity(n);
        for i in 0..n {
            let c = cats[i % cats.len()];
            vals.push(c);
            vals.push((i % 17) as Float * 0.1);
            y.push(if c == 0.0 || c == 5.0 { 1.0 } else { -1.0 });
        }
        let x = DMatrix::dense(vals, n, 2);
        let mut params = simple_params(2);
        params.categorical = vec![0];
        params.eta = 1.0;
        let mut c = MultiDeviceCoordinator::from_dmatrix(&x, params).unwrap();
        let grads: Vec<GradPair> = y.iter().map(|&t| GradPair::new(-t, 1.0)).collect();
        let r = c.build_tree(&grads).unwrap();
        assert!(
            r.tree.nodes.iter().any(|nd| nd.cats != 0),
            "training should pick a membership split"
        );
        // quantised training routing == float traversal on the raw values
        for row in 0..n {
            let pred = r.tree.predict_row(&x, row);
            assert!(
                (pred - r.deltas[row]).abs() < 1e-6,
                "row {row}: {pred} vs {}",
                r.deltas[row]
            );
        }
    }

    #[test]
    fn lossguide_respects_max_leaves() {
        let g = generate(&DatasetSpec::higgs_like(3000), 9);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let mut params = simple_params(1);
        params.policy = GrowthPolicy::LossGuide;
        params.tree.max_depth = 0;
        params.tree.max_leaves = 8;
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params).unwrap();
        let r = c.build_tree(&grads).unwrap();
        assert!(r.tree.n_leaves() <= 8);
        assert!(r.tree.n_leaves() >= 4, "should actually grow");
    }

    #[test]
    fn stats_are_populated() {
        let g = generate(&DatasetSpec::higgs_like(2000), 11);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, simple_params(4)).unwrap();
        let r = c.build_tree(&grads).unwrap();
        assert_eq!(r.stats.hist_secs.len(), 4);
        assert!(r.stats.hist_rounds >= 1);
        assert!(r.stats.comm_bytes_per_device > 0);
        assert!(r.stats.simulated_secs > 0.0);
        assert!(r.stats.hist_cells > 0);
        // real wall-clock of the concurrent device phases is recorded
        assert!(r.stats.hist_wall_secs > 0.0);
        assert!(r.stats.device_wall_secs() >= r.stats.hist_wall_secs);
    }

    #[test]
    fn thread_count_is_invisible_in_results() {
        // > ROW_CHUNK rows per device (train = 0.8 * 24_000 over 2
        // devices = 9_600) so chunk merging actually engages; shared cuts
        // so only the engine (not the sketch shards) varies
        let g = generate(&DatasetSpec::higgs_like(24_000), 31);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let base = simple_params(2);
        let cuts = MultiDeviceCoordinator::distributed_cuts(&g.train.x, &base).unwrap();
        let mut reference: Option<(RegTree, Vec<Float>)> = None;
        for threads in [1usize, 2, 8] {
            let mut params = simple_params(2);
            params.threads = threads;
            // cuts themselves must not depend on the thread count either
            assert_eq!(
                MultiDeviceCoordinator::distributed_cuts(&g.train.x, &params).unwrap(),
                cuts,
                "threads = {threads}"
            );
            let mut c = MultiDeviceCoordinator::with_cuts(
                &g.train.x,
                params,
                cuts.clone(),
                Box::new(NativeBackend::default()),
            )
            .unwrap();
            let r = c.build_tree(&grads).unwrap();
            match &reference {
                None => reference = Some((r.tree, r.deltas)),
                Some((t, d)) => {
                    assert_eq!(&r.tree, t, "threads = {threads}");
                    assert_eq!(&r.deltas, d, "threads = {threads}");
                }
            }
        }
    }

    #[test]
    fn cuts_are_device_count_invariant() {
        // the streaming sketch folds in global row order, so the device
        // count no longer perturbs quantisation
        let g = generate(&DatasetSpec::higgs_like(1000), 21);
        let reference =
            MultiDeviceCoordinator::distributed_cuts(&g.train.x, &simple_params(1)).unwrap();
        for p in [2usize, 3, 8] {
            let cuts =
                MultiDeviceCoordinator::distributed_cuts(&g.train.x, &simple_params(p)).unwrap();
            assert_eq!(cuts, reference, "p={p}");
        }
    }

    #[test]
    fn from_source_matches_from_dmatrix() {
        use crate::data::source::DMatrixSource;
        // streamed shards must be byte-identical to in-memory construction
        // for every batch size, on dense and sparse data, packed or not
        for (spec, seed) in [
            (DatasetSpec::higgs_like(600), 23),
            (DatasetSpec::bosch_like(400), 29),
        ] {
            let g = generate(&spec, seed);
            for compress in [false, true] {
                let mut params = simple_params(2);
                params.compress = compress;
                let reference =
                    MultiDeviceCoordinator::from_dmatrix(&g.train.x, params.clone()).unwrap();
                for batch in [7usize, 64, g.train.n_rows()] {
                    let mut src = DMatrixSource::from_dataset(&g.train, batch);
                    let (c, meta) =
                        MultiDeviceCoordinator::from_source(&mut src, params.clone()).unwrap();
                    assert_eq!(c.cuts, reference.cuts, "batch={batch}");
                    assert_eq!(meta.n_rows, g.train.n_rows());
                    assert_eq!(meta.labels, g.train.y);
                    for (a, b) in c.devices.iter().zip(reference.devices.iter()) {
                        assert_eq!(a.row_offset, b.row_offset);
                        match (&a.storage, &b.storage) {
                            (ShardStorage::Quantized(x), ShardStorage::Quantized(y)) => {
                                assert_eq!(x.bins, y.bins, "batch={batch}");
                                assert_eq!(x.row_stride, y.row_stride);
                                assert_eq!(x.dense, y.dense);
                            }
                            (ShardStorage::Compressed(x), ShardStorage::Compressed(y)) => {
                                assert_eq!(x.decode().bins, y.decode().bins, "batch={batch}");
                                assert_eq!(x.bytes(), y.bytes());
                            }
                            _ => panic!("storage kind mismatch"),
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn device_bytes_reported() {
        let g = generate(&DatasetSpec::higgs_like(2000), 13);
        let mut pc = simple_params(4);
        pc.compress = true;
        let c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, pc).unwrap();
        let bytes = c.device_bytes();
        assert_eq!(bytes.len(), 4);
        assert!(bytes.iter().all(|&b| b > 0));
    }

    #[test]
    fn gradient_length_mismatch_is_error() {
        let g = generate(&DatasetSpec::higgs_like(1000), 15);
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, simple_params(1)).unwrap();
        assert!(c.build_tree(&vec![GradPair::default(); 10]).is_err());
    }
}
