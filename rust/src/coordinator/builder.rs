//! The multi-device tree builder: a faithful implementation of the paper's
//! Algorithm 1 plus the subtraction-trick optimisation, per-phase timing
//! and the simulated multi-GPU clock (DESIGN.md §5).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::comm::{allreduce, CostModel};
use crate::coordinator::device::{DeviceShard, HistBackend, NativeBackend, ShardStorage};
use crate::coordinator::CoordinatorParams;
use crate::compress::CompressedMatrix;
use crate::data::DMatrix;
use crate::exec::ExecContext;
use crate::hist::{subtract, GradPairF64, Histogram};
use crate::quantile::{HistogramCuts, Quantizer, WQSummary};
use crate::quantile::sketch::SketchBuilder;
use crate::tree::{ExpandEntry, GrowthPolicy, PolicyQueue, RegTree, SplitEvaluator};
use crate::{Float, GradPair};

/// Result of building one tree.
pub struct TreeBuildResult {
    pub tree: RegTree,
    /// Per-global-row margin delta (the new tree's leaf value for that
    /// row, already scaled by eta) — applied by the booster without
    /// re-traversing the tree.
    pub deltas: Vec<Float>,
    pub stats: BuildStats,
}

/// Per-tree timing/traffic statistics, the raw material of the Table 2 /
/// Figure 2 "gpu" rows.
///
/// Per-device seconds are measured **under the configured engine**: with
/// `threads > 1` the simulated devices run concurrently on shared host
/// cores (and fork chunk-parallel budgets), so `hist_secs` /
/// `partition_secs` — and therefore `simulated_secs`, which folds their
/// per-round max — reflect that contention. For the paper-faithful,
/// host-independent simulated clock, pin `threads = 1` as
/// `benches/fig2_scaling.rs` does for its device sweep.
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    /// Histogram-build seconds, per device (measured).
    pub hist_secs: Vec<f64>,
    /// Repartition seconds, per device (measured).
    pub partition_secs: Vec<f64>,
    /// Split-evaluation seconds (coordinator-side).
    pub split_secs: f64,
    /// Host seconds actually spent merging histograms.
    pub allreduce_host_secs: f64,
    /// Simulated collective seconds under the cost model.
    pub allreduce_sim_secs: f64,
    /// Bytes sent per device across all collectives.
    pub comm_bytes_per_device: usize,
    /// Number of histogram rounds (== number of expanded nodes + 1 root).
    pub hist_rounds: usize,
    /// Quantised cells visited by histogram builds (rows × row_stride),
    /// for throughput reporting.
    pub hist_cells: u64,
    /// Simulated multi-device wall-clock: Σ_round [max_d(compute_d) +
    /// comm_sim(round)].
    pub simulated_secs: f64,
    /// **Measured** wall-clock of the histogram device phase: elapsed time
    /// of each round's concurrent shard execution, summed over rounds.
    /// With `threads > 1` this drops below `Σ hist_secs`.
    pub hist_wall_secs: f64,
    /// **Measured** wall-clock of the repartition device phase.
    pub partition_wall_secs: f64,
}

impl BuildStats {
    fn new(p: usize) -> Self {
        BuildStats {
            hist_secs: vec![0.0; p],
            partition_secs: vec![0.0; p],
            ..Default::default()
        }
    }

    /// Merge another tree's stats into an accumulated total.
    pub fn accumulate(&mut self, other: &BuildStats) {
        if self.hist_secs.len() < other.hist_secs.len() {
            self.hist_secs.resize(other.hist_secs.len(), 0.0);
            self.partition_secs.resize(other.partition_secs.len(), 0.0);
        }
        for (a, b) in self.hist_secs.iter_mut().zip(&other.hist_secs) {
            *a += b;
        }
        for (a, b) in self.partition_secs.iter_mut().zip(&other.partition_secs) {
            *a += b;
        }
        self.split_secs += other.split_secs;
        self.allreduce_host_secs += other.allreduce_host_secs;
        self.allreduce_sim_secs += other.allreduce_sim_secs;
        self.comm_bytes_per_device += other.comm_bytes_per_device;
        self.hist_rounds += other.hist_rounds;
        self.hist_cells += other.hist_cells;
        self.simulated_secs += other.simulated_secs;
        self.hist_wall_secs += other.hist_wall_secs;
        self.partition_wall_secs += other.partition_wall_secs;
    }

    /// Total measured device compute (sum over all devices — the work, not
    /// the wall-clock; concurrent execution makes wall < this).
    pub fn total_compute_secs(&self) -> f64 {
        self.hist_secs.iter().sum::<f64>()
            + self.partition_secs.iter().sum::<f64>()
            + self.split_secs
    }

    /// Measured wall-clock of the two thread-parallel device phases — the
    /// quantity the `threads` sweep in `benches/fig2_scaling.rs` reports.
    pub fn device_wall_secs(&self) -> f64 {
        self.hist_wall_secs + self.partition_wall_secs
    }
}

/// The Algorithm 1 coordinator over `p` simulated devices.
pub struct MultiDeviceCoordinator {
    pub params: CoordinatorParams,
    pub cuts: HistogramCuts,
    pub devices: Vec<DeviceShard>,
    backend: Box<dyn HistBackend>,
    evaluator: SplitEvaluator,
    n_rows: usize,
    /// Per-tree column-sampling stream (`colsample_bytree`).
    col_rng: crate::util::Pcg64,
    /// Thread budget for the real parallel engine (`params.threads`).
    exec: ExecContext,
}

impl MultiDeviceCoordinator {
    /// Shard `x` over `params.n_devices` devices, run the distributed
    /// quantile sketch (per-device sketch + merge — the multi-GPU §2.1
    /// pipeline), quantise and optionally compress every shard.
    pub fn from_dmatrix(x: &DMatrix, params: CoordinatorParams) -> Result<Self> {
        Self::with_backend(x, params, Box::new(NativeBackend))
    }

    /// Same, with an explicit histogram backend (the XLA runtime path).
    pub fn with_backend(
        x: &DMatrix,
        params: CoordinatorParams,
        backend: Box<dyn HistBackend>,
    ) -> Result<Self> {
        let cuts = Self::distributed_cuts(x, &params)?;
        Self::with_cuts(x, params, cuts, backend)
    }

    /// Distributed quantile generation (§2.1 multi-GPU pipeline): each
    /// device sketches its shard's columns — one pool task per column, the
    /// per-worker `WQSummary`s folded back with the existing sketch merge
    /// op — then per-device sketches are merged in fixed device order (the
    /// same reduction a real deployment would all-reduce). The task
    /// boundaries and merge order depend only on the data layout, so cuts
    /// are identical at every thread count.
    pub fn distributed_cuts(x: &DMatrix, params: &CoordinatorParams) -> Result<HistogramCuts> {
        let p = params.n_devices;
        ensure!(p >= 1, "need at least one device");
        let n = x.n_rows();
        ensure!(n >= p, "fewer rows ({n}) than devices ({p})");
        let exec = ExecContext::new(params.threads);
        let bounds: Vec<usize> = (0..=p).map(|d| d * n / p).collect();
        let limit = (params.max_bins * 8).max(64);
        let mut merged: Vec<SketchBuilder> =
            (0..x.n_cols()).map(|_| SketchBuilder::new(limit)).collect();
        for d in 0..p {
            let lo = bounds[d];
            let hi = bounds[d + 1];
            let local: Vec<SketchBuilder> = exec.run_indexed(x.n_cols(), |col| {
                let mut b = SketchBuilder::new(limit);
                x.for_each_in_column(col, |row, v| {
                    if row >= lo && row < hi {
                        b.push(v, 1.0);
                    }
                });
                b
            });
            for (m, l) in merged.iter_mut().zip(local.into_iter()) {
                m.merge(l);
            }
        }
        let summaries: Vec<WQSummary> = merged.into_iter().map(|b| b.finish()).collect();
        Ok(HistogramCuts::from_summaries(&summaries, params.max_bins))
    }

    /// Construct with externally supplied cuts (shared across coordinators
    /// for cross-device-count determinism tests, or reused across boosting
    /// iterations).
    pub fn with_cuts(
        x: &DMatrix,
        params: CoordinatorParams,
        cuts: HistogramCuts,
        backend: Box<dyn HistBackend>,
    ) -> Result<Self> {
        let p = params.n_devices;
        ensure!(p >= 1, "need at least one device");
        let n = x.n_rows();
        ensure!(n >= p, "fewer rows ({n}) than devices ({p})");
        let exec = ExecContext::new(params.threads);
        let bounds: Vec<usize> = (0..=p).map(|d| d * n / p).collect();
        let quantizer = Quantizer::new(cuts.clone());

        // quantise + compress every shard concurrently (one task per
        // device, each shard's content independent of the others)
        let devices: Vec<DeviceShard> = exec.run_indexed(p, |d| {
            let rows: Vec<usize> = (bounds[d]..bounds[d + 1]).collect();
            let shard_x = x.take_rows(&rows);
            let qm = quantizer.quantize(&shard_x);
            let storage = if params.compress {
                ShardStorage::Compressed(CompressedMatrix::from_quantized(&qm))
            } else {
                ShardStorage::Quantized(qm)
            };
            DeviceShard::new(d, bounds[d], storage)
        });

        let evaluator = SplitEvaluator::new(params.tree.clone());
        let col_rng = crate::util::Pcg64::new(params.seed ^ 0xc01_5a3f);
        Ok(MultiDeviceCoordinator {
            params,
            cuts,
            devices,
            backend,
            evaluator,
            n_rows: n,
            col_rng,
            exec,
        })
    }

    /// Draw the per-tree feature mask (`None` when colsample is off).
    fn sample_columns(&mut self) -> Option<Vec<bool>> {
        let rate = self.params.colsample_bytree;
        if rate >= 1.0 {
            return None;
        }
        let n_feat = self.cuts.n_features();
        let k = ((n_feat as f64 * rate).ceil() as usize).clamp(1, n_feat);
        let chosen = self.col_rng.sample_indices(n_feat, k);
        let mut mask = vec![false; n_feat];
        for i in chosen {
            mask[i] = true;
        }
        Some(mask)
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_bins(&self) -> usize {
        self.cuts.total_bins()
    }

    /// Resident feature-matrix bytes per device (paper's "600 MB/GPU").
    pub fn device_bytes(&self) -> Vec<usize> {
        self.devices.iter().map(|d| d.storage.bytes()).collect()
    }

    /// All-reduce a set of per-device f64 buffers; returns (merged copy,
    /// host seconds, simulated seconds, bytes/device).
    fn collective(&self, mut bufs: Vec<Vec<f64>>) -> (Vec<f64>, f64, f64, usize) {
        let host_t = Instant::now();
        let stats = allreduce(self.params.allreduce, &mut bufs);
        let host = host_t.elapsed().as_secs_f64();
        let sim = self.params.cost.time(&stats);
        let merged = bufs.into_iter().next().unwrap();
        (merged, host, sim, stats.bytes_per_device)
    }

    /// Build one tree from the global gradient vector — Algorithm 1.
    pub fn build_tree(&mut self, gradients: &[GradPair]) -> Result<TreeBuildResult> {
        ensure!(gradients.len() == self.n_rows, "gradient length mismatch");
        let p = self.devices.len();
        let mut stats = BuildStats::new(p);
        let eta = self.params.eta;

        // distribute gradients (every shard copies its slice concurrently)
        self.exec.parallel_map_mut(&mut self.devices, |_, d| {
            let lo = d.row_offset;
            let hi = lo + d.n_rows();
            d.begin_tree(&gradients[lo..hi]);
        });

        // root gradient sum: tiny collective over (g, h) pairs (each
        // device's sum is computed serially within the device, so the
        // value is independent of the thread count)
        let sums: Vec<Vec<f64>> = self.exec.parallel_map(&self.devices, |_, d| {
            let (g, h) = d.local_sum();
            vec![g, h]
        });
        let (root_vec, host, sim, bytes) = self.collective(sums);
        stats.allreduce_host_secs += host;
        stats.allreduce_sim_secs += sim;
        stats.comm_bytes_per_device += bytes;
        let root_sum = GradPairF64::new(root_vec[0], root_vec[1]);

        let mut tree = RegTree::new_root(
            (eta * self.evaluator.leaf_weight(root_sum)) as Float,
            root_sum.hess as Float,
        );

        // root histogram round
        let mut hist_store: HashMap<usize, Histogram> = HashMap::new();
        let (root_hist, round_secs) = self.histogram_round(0, &mut stats)?;
        stats.simulated_secs += round_secs;
        hist_store.insert(0, root_hist);

        let feature_mask = self.sample_columns();
        let root_bounds = crate::tree::split::NodeBounds::default();
        let mut queue = PolicyQueue::new(self.params.policy);
        let split_t = Instant::now();
        if let Some(split) = self.evaluator.evaluate_bounded(
            hist_store.get(&0).unwrap(),
            &self.cuts,
            root_sum,
            feature_mask.as_deref(),
            root_bounds,
        ) {
            queue.push(ExpandEntry {
                nid: 0,
                depth: 0,
                split,
                node_sum: root_sum,
                bounds: root_bounds,
                timestamp: 0,
            });
        }
        stats.split_secs += split_t.elapsed().as_secs_f64();

        let max_depth = self.params.tree.max_depth;
        let max_leaves = self.params.tree.max_leaves;

        while let Some(entry) = queue.pop() {
            if max_leaves > 0 && tree.n_leaves() >= max_leaves {
                break;
            }
            let s = &entry.split;
            // materialise the split in the tree; leaf weights respect the
            // node's monotone bounds
            let left_value =
                (eta * self.evaluator.weight_clamped(s.left_sum, entry.bounds)) as Float;
            let right_value =
                (eta * self.evaluator.weight_clamped(s.right_sum, entry.bounds)) as Float;
            let (left_bounds, right_bounds) = self.evaluator.child_bounds(s, entry.bounds);
            let (left, right) = tree.apply_split(
                entry.nid,
                s.feature,
                s.threshold,
                s.default_left,
                s.gain as Float,
                left_value,
                s.left_sum.hess as Float,
                right_value,
                s.right_sum.hess as Float,
            );

            // RepartitionInstances on every device — all shards
            // concurrently on the pool (repartitioning never touches the
            // histogram backend, so it parallelises regardless of
            // backend), each shard chunk-parallel under its forked budget
            let cuts = self.cuts.clone();
            let dev_exec = self.exec.fork(p);
            let part_wall = Instant::now();
            let part_results: Vec<(usize, usize, f64)> =
                self.exec.parallel_map_mut(&mut self.devices, |_, dev| {
                    let t = Instant::now();
                    let (nl, nr) =
                        dev.repartition(entry.nid, s, left, right, &cuts, &dev_exec);
                    (nl, nr, t.elapsed().as_secs_f64())
                });
            stats.partition_wall_secs += part_wall.elapsed().as_secs_f64();
            let mut n_left_total = 0usize;
            let mut n_right_total = 0usize;
            let mut part_secs = vec![0.0f64; p];
            for (di, &(nl, nr, secs)) in part_results.iter().enumerate() {
                part_secs[di] = secs;
                stats.partition_secs[di] += secs;
                n_left_total += nl;
                n_right_total += nr;
            }

            // children at depth+1; can they be expanded further?
            let child_depth = entry.depth + 1;
            let depth_ok = max_depth == 0 || child_depth < max_depth;

            if !depth_ok {
                hist_store.remove(&entry.nid);
                continue;
            }

            // BuildPartialHistograms for the smaller child + AllReduce;
            // sibling via subtraction from the parent histogram.
            let (small_nid, _large_nid) = if n_left_total <= n_right_total {
                (left, right)
            } else {
                (right, left)
            };
            let (small_hist, mut round_secs) = self.histogram_round(small_nid, &mut stats)?;
            // repartition happens within the same device round as the
            // histogram build: add the slowest device's partition time
            round_secs += part_secs.iter().cloned().fold(0.0, f64::max);
            stats.simulated_secs += round_secs;

            let parent_hist = hist_store
                .remove(&entry.nid)
                .expect("parent histogram must exist");
            let large_hist = if self.params.subtraction {
                subtract(&parent_hist, &small_hist)
            } else {
                // A3 ablation: build the larger sibling from its rows too
                let (h, extra) = self.histogram_round(_large_nid, &mut stats)?;
                stats.simulated_secs += extra;
                h
            };

            // EvaluateSplit for both children; queue feasible expansions
            let split_t = Instant::now();
            let (left_hist, right_hist) = if small_nid == left {
                (&small_hist, &large_hist)
            } else {
                (&large_hist, &small_hist)
            };
            let left_split = self.evaluator.evaluate_bounded(
                left_hist,
                &self.cuts,
                s.left_sum,
                feature_mask.as_deref(),
                left_bounds,
            );
            let right_split = self.evaluator.evaluate_bounded(
                right_hist,
                &self.cuts,
                s.right_sum,
                feature_mask.as_deref(),
                right_bounds,
            );
            stats.split_secs += split_t.elapsed().as_secs_f64();

            if let Some(ls) = left_split {
                queue.push(ExpandEntry {
                    nid: left,
                    depth: child_depth,
                    split: ls,
                    node_sum: s.left_sum,
                    bounds: left_bounds,
                    timestamp: 0,
                });
                hist_store.entry(left).or_insert_with(|| left_hist.clone());
            }
            if let Some(rs) = right_split {
                queue.push(ExpandEntry {
                    nid: right,
                    depth: child_depth,
                    split: rs,
                    node_sum: s.right_sum,
                    bounds: right_bounds,
                    timestamp: 0,
                });
                hist_store.entry(right).or_insert_with(|| right_hist.clone());
            }
        }

        // margin deltas from final leaf assignment — no tree re-traversal
        let mut deltas = vec![0.0 as Float; self.n_rows];
        for dev in &self.devices {
            for (nid, rows) in dev.partitioner.leaf_of_rows() {
                let v = tree.nodes[nid].leaf_value;
                for &r in rows {
                    deltas[dev.row_offset + r as usize] = v;
                }
            }
        }

        Ok(TreeBuildResult {
            tree,
            deltas,
            stats,
        })
    }

    /// One histogram round for node `nid`: partial build on every device
    /// (measured), then the all-reduce merge. With a thread-safe backend
    /// (`HistBackend::as_parallel`) the shards run **concurrently** on the
    /// pool, each with a forked chunk-parallel budget; a pinned backend
    /// (the Rc-based XLA runtime) keeps the serial device loop on this
    /// thread. Partials enter the collective in device order either way,
    /// so the merged histogram is identical. Returns the merged histogram
    /// and this round's simulated wall-clock contribution
    /// `max_d(build_d) + comm`.
    fn histogram_round(
        &mut self,
        nid: usize,
        stats: &mut BuildStats,
    ) -> Result<(Histogram, f64)> {
        let n_bins = self.cuts.total_bins();
        let p = self.devices.len();
        let wall_t = Instant::now();
        // per-device (flat partial, build seconds, cells visited)
        let use_pool = self.exec.threads() > 1 && self.backend.as_parallel().is_some();
        let results: Vec<Result<(Vec<f64>, f64, u64)>> = if use_pool {
            let pb = self.backend.as_parallel().expect("checked above");
            let dev_exec = self.exec.fork(p);
            self.exec.parallel_map(&self.devices, |_, dev| {
                let rows = dev.partitioner.node_rows(nid);
                let mut h = Histogram::zeros(n_bins);
                let t = Instant::now();
                pb.build_histogram_shard(dev, rows, &mut h, &dev_exec)?;
                let cells = (rows.len() * dev.storage.row_stride()) as u64;
                Ok((h.to_flat(), t.elapsed().as_secs_f64(), cells))
            })
        } else {
            // pinned executor path: the backend owns thread-bound state
            // (or threads = 1), so every shard executes on this thread
            let devices = &self.devices;
            let backend = &mut self.backend;
            let exec = self.exec;
            devices
                .iter()
                .map(|dev| {
                    let rows = dev.partitioner.node_rows(nid);
                    let mut h = Histogram::zeros(n_bins);
                    let t = Instant::now();
                    backend.build_histogram(dev, rows, &mut h, &exec)?;
                    let cells = (rows.len() * dev.storage.row_stride()) as u64;
                    Ok((h.to_flat(), t.elapsed().as_secs_f64(), cells))
                })
                .collect()
        };
        stats.hist_wall_secs += wall_t.elapsed().as_secs_f64();

        let mut partials: Vec<Vec<f64>> = Vec::with_capacity(p);
        let mut max_build = 0.0f64;
        for (di, r) in results.into_iter().enumerate() {
            let (flat, secs, cells) = r?;
            stats.hist_secs[di] += secs;
            stats.hist_cells += cells;
            max_build = max_build.max(secs);
            partials.push(flat);
        }
        let (merged, host, sim, bytes) = self.collective(partials);
        stats.allreduce_host_secs += host;
        stats.allreduce_sim_secs += sim;
        stats.comm_bytes_per_device += bytes;
        stats.hist_rounds += 1;
        Ok((Histogram::from_flat(&merged), max_build + sim))
    }
}

/// Convenience: cost-model-only scaling projection. Given measured
/// single-device per-round compute and histogram size, project the
/// simulated wall-clock for `p` devices (used by the Figure 2 bench for
/// the analytic overlay; the measured path re-runs the coordinator).
pub fn project_scaling(
    single_device_compute_secs: f64,
    hist_elems: usize,
    rounds: usize,
    p: usize,
    cost: &CostModel,
) -> f64 {
    let per_device = single_device_compute_secs / p as f64;
    per_device + rounds as f64 * cost.ring_time(p, hist_elems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::tree::TreeParams;

    fn simple_params(p: usize) -> CoordinatorParams {
        CoordinatorParams {
            n_devices: p,
            compress: false,
            tree: TreeParams {
                max_depth: 3,
                ..Default::default()
            },
            max_bins: 16,
            ..Default::default()
        }
    }

    fn logistic_grads(ds: &crate::data::Dataset, margins: &[Float]) -> Vec<GradPair> {
        ds.y
            .iter()
            .zip(margins.iter())
            .map(|(&y, &m)| {
                let pr = 1.0 / (1.0 + (-m).exp());
                GradPair::new(pr - y, (pr * (1.0 - pr)).max(1e-6))
            })
            .collect()
    }

    #[test]
    fn single_device_builds_reasonable_tree() {
        let g = generate(&DatasetSpec::higgs_like(2000), 1);
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, simple_params(1)).unwrap();
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let r = c.build_tree(&grads).unwrap();
        assert!(r.tree.n_leaves() >= 2, "tree should split");
        assert!(r.tree.max_depth() <= 3);
        assert_eq!(r.deltas.len(), g.train.n_rows());
        // deltas reduce the logistic loss direction: correlation with -grad
        let mut corr = 0.0f64;
        for (d, gp) in r.deltas.iter().zip(grads.iter()) {
            corr += (*d as f64) * (-gp.grad as f64);
        }
        assert!(corr > 0.0, "tree should move against the gradient");
    }

    #[test]
    fn multi_device_equals_single_device() {
        let g = generate(&DatasetSpec::higgs_like(3000), 7);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        // shared cuts isolate the invariant: same quantisation => identical
        // tree regardless of device count (the sketch itself merges in a
        // p-dependent order and so differs slightly across p).
        let cuts = MultiDeviceCoordinator::distributed_cuts(&g.train.x, &simple_params(1))
            .unwrap();
        let mut trees = Vec::new();
        for p in [1usize, 2, 4, 8] {
            let mut c = MultiDeviceCoordinator::with_cuts(
                &g.train.x,
                simple_params(p),
                cuts.clone(),
                Box::new(NativeBackend),
            )
            .unwrap();
            let r = c.build_tree(&grads).unwrap();
            trees.push((p, r.tree));
        }
        let (_, ref t1) = trees[0];
        for (p, t) in &trees[1..] {
            assert_eq!(t.n_nodes(), t1.n_nodes(), "p={p} node count");
            for (a, b) in t.nodes.iter().zip(t1.nodes.iter()) {
                assert_eq!(a.feature, b.feature, "p={p}");
                assert_eq!(a.left, b.left, "p={p}");
                assert!((a.threshold - b.threshold).abs() < 1e-6, "p={p}");
                assert!((a.leaf_value - b.leaf_value).abs() < 1e-5, "p={p}");
            }
        }
    }

    #[test]
    fn compressed_equals_uncompressed() {
        let g = generate(&DatasetSpec::higgs_like(2000), 3);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let mut pc = simple_params(2);
        pc.compress = true;
        let mut pu = simple_params(2);
        pu.compress = false;
        let mut cc = MultiDeviceCoordinator::from_dmatrix(&g.train.x, pc).unwrap();
        let mut cu = MultiDeviceCoordinator::from_dmatrix(&g.train.x, pu).unwrap();
        let rc = cc.build_tree(&grads).unwrap();
        let ru = cu.build_tree(&grads).unwrap();
        assert_eq!(rc.tree, ru.tree);
        assert_eq!(rc.deltas, ru.deltas);
    }

    #[test]
    fn deltas_match_tree_predictions() {
        let g = generate(&DatasetSpec::year_prediction_like(1500), 5);
        let mut params = simple_params(2);
        params.eta = 0.5;
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params).unwrap();
        // squared-error gradients around mean
        let mean: f32 = g.train.y.iter().sum::<f32>() / g.train.y.len() as f32;
        let grads: Vec<GradPair> = g
            .train
            .y
            .iter()
            .map(|&y| GradPair::new(mean - y, 1.0))
            .collect();
        let r = c.build_tree(&grads).unwrap();
        // NOTE: deltas come from the quantised routing; tree.predict_row
        // uses raw values with the recovered thresholds — they must agree.
        for row in 0..g.train.n_rows() {
            let pred = r.tree.predict_row(&g.train.x, row);
            assert!(
                (pred - r.deltas[row]).abs() < 1e-6,
                "row {row}: {pred} vs {}",
                r.deltas[row]
            );
        }
    }

    #[test]
    fn lossguide_respects_max_leaves() {
        let g = generate(&DatasetSpec::higgs_like(3000), 9);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let mut params = simple_params(1);
        params.policy = GrowthPolicy::LossGuide;
        params.tree.max_depth = 0;
        params.tree.max_leaves = 8;
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params).unwrap();
        let r = c.build_tree(&grads).unwrap();
        assert!(r.tree.n_leaves() <= 8);
        assert!(r.tree.n_leaves() >= 4, "should actually grow");
    }

    #[test]
    fn stats_are_populated() {
        let g = generate(&DatasetSpec::higgs_like(2000), 11);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, simple_params(4)).unwrap();
        let r = c.build_tree(&grads).unwrap();
        assert_eq!(r.stats.hist_secs.len(), 4);
        assert!(r.stats.hist_rounds >= 1);
        assert!(r.stats.comm_bytes_per_device > 0);
        assert!(r.stats.simulated_secs > 0.0);
        assert!(r.stats.hist_cells > 0);
        // real wall-clock of the concurrent device phases is recorded
        assert!(r.stats.hist_wall_secs > 0.0);
        assert!(r.stats.device_wall_secs() >= r.stats.hist_wall_secs);
    }

    #[test]
    fn thread_count_is_invisible_in_results() {
        // > ROW_CHUNK rows per device (train = 0.8 * 24_000 over 2
        // devices = 9_600) so chunk merging actually engages; shared cuts
        // so only the engine (not the sketch shards) varies
        let g = generate(&DatasetSpec::higgs_like(24_000), 31);
        let grads = logistic_grads(&g.train, &vec![0.0; g.train.n_rows()]);
        let base = simple_params(2);
        let cuts = MultiDeviceCoordinator::distributed_cuts(&g.train.x, &base).unwrap();
        let mut reference: Option<(RegTree, Vec<Float>)> = None;
        for threads in [1usize, 2, 8] {
            let mut params = simple_params(2);
            params.threads = threads;
            // cuts themselves must not depend on the thread count either
            assert_eq!(
                MultiDeviceCoordinator::distributed_cuts(&g.train.x, &params).unwrap(),
                cuts,
                "threads = {threads}"
            );
            let mut c = MultiDeviceCoordinator::with_cuts(
                &g.train.x,
                params,
                cuts.clone(),
                Box::new(NativeBackend),
            )
            .unwrap();
            let r = c.build_tree(&grads).unwrap();
            match &reference {
                None => reference = Some((r.tree, r.deltas)),
                Some((t, d)) => {
                    assert_eq!(&r.tree, t, "threads = {threads}");
                    assert_eq!(&r.deltas, d, "threads = {threads}");
                }
            }
        }
    }

    #[test]
    fn device_bytes_reported() {
        let g = generate(&DatasetSpec::higgs_like(2000), 13);
        let mut pc = simple_params(4);
        pc.compress = true;
        let c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, pc).unwrap();
        let bytes = c.device_bytes();
        assert_eq!(bytes.len(), 4);
        assert!(bytes.iter().all(|&b| b > 0));
    }

    #[test]
    fn gradient_length_mismatch_is_error() {
        let g = generate(&DatasetSpec::higgs_like(1000), 15);
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, simple_params(1)).unwrap();
        assert!(c.build_tree(&vec![GradPair::default(); 10]).is_err());
    }
}
