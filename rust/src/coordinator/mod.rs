//! Multi-device decision tree construction — Algorithm 1 of the paper, the
//! system's coordination contribution.
//!
//! # Ingestion: the two-pass streaming pipeline
//!
//! Each simulated device owns a contiguous shard of training rows in
//! quantised (optionally bit-packed, §2.2) form. Shards are built by
//! streaming the input through a [`crate::data::BatchSource`] twice
//! ([`MultiDeviceCoordinator::from_source`]):
//!
//! * **pass 1** folds every bounded batch into the per-column incremental
//!   quantile sketch ([`crate::data::scan_source`]) and collects labels,
//!   qid groups and per-row widths — freezing the
//!   [`crate::quantile::HistogramCuts`];
//! * **pass 2** re-streams the source, quantises each batch against the
//!   frozen cuts and appends each row's symbols straight into its shard's
//!   bit-packed pages ([`crate::compress::CompressedMatrixBuilder`]).
//!
//! The raw float matrix never materializes: peak transient float bytes
//! are O(`batch_rows × n_cols`). The legacy constructors
//! ([`MultiDeviceCoordinator::from_dmatrix`] /
//! [`MultiDeviceCoordinator::with_cuts`]) are thin adapters that wrap the
//! in-memory matrix in a [`crate::data::DMatrixSource`] and ride the same
//! pipeline, so streamed and in-memory construction are bit-identical by
//! construction — for every batch size, device count and thread count.
//!
//! # External memory: the resident-vs-spilled page lifecycle
//!
//! With [`CoordinatorParams::max_resident_pages`] `> 0` the packed pages
//! themselves stop being a full-size allocation. Pass 2 pushes each row
//! into a [`crate::compress::page::PagedMatrixBuilder`], which seals a
//! page every [`CoordinatorParams::page_rows`] rows and **spills** it to
//! the shard's temp page file (header: rows, bit-width, word count,
//! checksum). Training then cycles every page through
//!
//! 1. **spilled** — on disk, owned by the shard's
//!    [`crate::compress::page::PageStore`];
//! 2. **resident** — loaded (and checksum-verified) into a ref-counted
//!    page handle, either by the histogram round's double-buffered
//!    prefetch worker or by the repartition cursor;
//! 3. **released** — the handle drops as the row walk leaves the page,
//!    and the bytes come off the store's resident counter.
//!
//! The peak-memory contract follows directly: per shard, resident packed
//! bytes never exceed `max_resident_pages × page_bytes` (histogram
//! prefetch accounts its queue + in-flight load + accumulating page
//! against the budget; repartition holds a single page). The measured
//! peak is reported per tree in [`BuildStats::peak_resident_page_bytes`],
//! alongside [`BuildStats::pages_loaded`] and the prefetch-hidden I/O
//! time. Everything else — cuts, trees, predictions, metrics — is
//! **bit-identical** to the fully resident run at every page size,
//! budget, thread count and device count, because the histogram
//! accumulation bracketing is a pure function of the row list (never the
//! page geometry); `rust/tests/external_memory.rs` pins this.
//!
//! # Prediction from the compressed representation
//!
//! Trained trees never need the float matrix again. The frozen
//! [`crate::quantile::HistogramCuts`] turn each tree's float thresholds
//! into bin thresholds
//! ([`crate::predict::quantised::threshold_to_bin`]; exact because
//! splits are chosen *at* cut values — the comparison `bin <
//! threshold_to_bin(t)` is precisely `v < t` for every representable
//! row), and [`MultiDeviceCoordinator::predict_margins`] /
//! [`MultiDeviceCoordinator::predict_leaf_indices`] traverse the shard
//! storage directly: resident packed words unpack inline, and a
//! [`ShardStorage::Paged`] shard streams its pages back through the same
//! prefetch worker and `max_resident_pages` budget as a histogram round
//! (pages cycle spilled → resident → released exactly as in training).
//! The per-round validation scoring inside the boosting loop uses the
//! same translation over a once-quantised valid set. All of it is
//! **bit-identical** to the float traversal at every page size, budget,
//! thread count and device count (`rust/tests/compressed_predict.rs`);
//! measured time lands in [`BuildStats::predict_wall_secs`], pages read
//! during prediction in [`BuildStats::pages_loaded`].
//!
//! Online serving (`xgb-tpu serve`, [`crate::serve`]) is the latency
//! end of this same chain: the trained trees are translated to bin
//! space once more ([`crate::predict::quantised::BinForest`]) and
//! flattened into the SoA [`crate::serve::FlatForest`], and requests
//! quantise row-locally against the frozen cuts — so a served response
//! is bit-identical to the shard/stream/paged prediction paths above,
//! with the same request-order determinism contract (see the serving
//! lifecycle section in the crate docs).
//!
//! # Tree construction
//!
//! Per expanded node the coordinator:
//!
//! 1. `RepartitionInstances` — every device re-sorts its shard's rows into
//!    the new leaves ([`crate::tree::RowPartitioner`]),
//! 2. `BuildPartialHistograms` — every device accumulates a partial
//!    gradient histogram for the *smaller* child over its rows (the
//!    subtraction trick derives the sibling),
//! 3. `AllReduceHistograms` — partial histograms are merged with the ring
//!    collective ([`crate::comm`]), traffic priced by the cost model,
//! 4. `EvaluateSplit` — the merged histogram is scanned for both children
//!    and feasible splits are queued under the configured growth policy
//!    (§2.3 "reconfigurable growth strategy").
//!
//! Device compute is *executed* (natively or through the AOT-compiled XLA
//! kernel via [`crate::runtime`]). With the native backend the shards run
//! **concurrently on OS threads** (the [`crate::exec`] engine, budgeted by
//! [`CoordinatorParams::threads`]); the Rc-based XLA backend stays pinned
//! to the coordinator's executor thread. Two clocks are reported per
//! round: the *measured* wall-clock of the concurrent execution
//! ([`BuildStats::hist_wall_secs`] / [`BuildStats::partition_wall_secs`])
//! and the *simulated* multi-device clock `max(per-device compute) +
//! collective cost` (DESIGN.md §5), which is exact for data-parallel
//! identical devices up to the comm model.

pub mod builder;
pub mod device;

pub use builder::{BuildStats, MultiDeviceCoordinator, TreeBuildResult};
pub use device::{DeviceShard, HistBackend, NativeBackend, ParallelHistBackend};

use crate::comm::{AllReduceAlgo, CostModel};
use crate::tree::{GrowthPolicy, TreeParams};

/// Configuration of the multi-device tree builder.
#[derive(Debug, Clone)]
pub struct CoordinatorParams {
    /// Number of simulated devices (the paper's GPUs).
    pub n_devices: usize,
    /// Store shards bit-packed (§2.2) instead of as raw u32 bins.
    pub compress: bool,
    /// Tree regularisation / size limits.
    pub tree: TreeParams,
    /// Growth strategy (§2.3).
    pub policy: GrowthPolicy,
    /// Collective algorithm for histogram merging.
    pub allreduce: AllReduceAlgo,
    /// Communication cost model for the simulated wall-clock.
    pub cost: CostModel,
    /// Learning rate applied to leaf values at construction time.
    pub eta: f64,
    /// Maximum bins per feature for quantisation.
    pub max_bins: usize,
    /// Use the subtraction trick (sibling = parent − built child). Off
    /// builds both children's histograms — the A3 ablation.
    pub subtraction: bool,
    /// Fraction of features considered per tree (`colsample_bytree`);
    /// 1.0 = all features.
    pub colsample_bytree: f64,
    /// Seed for the per-tree column sample.
    pub seed: u64,
    /// Worker-thread budget for the real parallel engine
    /// ([`crate::exec`]): device shards run concurrently and the per-shard
    /// hot loops are chunk-parallel. `0` = all cores, `1` = serial.
    /// Results are bit-identical for every value (see [`crate::exec`]).
    pub threads: usize,
    /// External-memory budget: maximum bit-packed pages each device shard
    /// may hold resident at once. `0` (the default) keeps shards fully
    /// resident; any positive value makes pass 2 of ingestion spill
    /// sealed pages to a per-shard temp file ([`crate::compress::page`])
    /// and histogram rounds stream them back page-at-a-time with async
    /// prefetch. Requires [`compress`](Self::compress). Trees,
    /// predictions and metrics are **bit-identical** to the fully
    /// resident run for every budget and page size
    /// (`rust/tests/external_memory.rs`).
    pub max_resident_pages: usize,
    /// Rows per sealed page when spilling (the page-size knob of the
    /// external-memory path). Ignored while fully resident.
    pub page_rows: usize,
    /// Real multi-process training over TCP ([`crate::comm::wire`]).
    /// `None` (the default) keeps every device in this process and merges
    /// with the in-process simulation. `Some` makes this process one rank
    /// of a wire ring: it builds only its own rank's device histograms
    /// and merges over loopback/LAN with the exact chunk boundaries and
    /// operand order of the simulation, so the trees are bit-identical
    /// to a single-process run with `n_devices ==` world size. Requires
    /// `n_devices == peers.len()` and [`AllReduceAlgo::Ring`].
    pub dist: Option<crate::comm::DistConfig>,
    /// Feature indices treated as categorical (empty = all numeric).
    /// Pass 1 of ingestion collects each flagged feature's exact distinct
    /// category set (codes must be integers in `[0, 64)`) and rebuilds
    /// its cuts one-bin-per-category
    /// ([`crate::data::scan_source_with_categories`]); split evaluation
    /// then searches membership partitions instead of thresholds.
    pub categorical: Vec<usize>,
}

impl Default for CoordinatorParams {
    fn default() -> Self {
        CoordinatorParams {
            n_devices: 1,
            compress: true,
            tree: TreeParams::default(),
            policy: GrowthPolicy::DepthWise,
            allreduce: AllReduceAlgo::Ring,
            cost: CostModel::default(),
            eta: 0.3,
            max_bins: 256,
            subtraction: true,
            colsample_bytree: 1.0,
            seed: 0,
            threads: 0,
            max_resident_pages: 0,
            page_rows: crate::compress::page::DEFAULT_PAGE_ROWS,
            dist: None,
            categorical: Vec::new(),
        }
    }
}
