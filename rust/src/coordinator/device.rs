//! A simulated accelerator device: owns one shard of the quantised
//! training matrix, its row partitioner, and the histogram backend that
//! executes the shard's compute (native Rust, or the AOT-compiled XLA
//! kernel via [`crate::runtime`]).

use anyhow::Result;

use crate::compress::page::PageStore;
use crate::compress::CompressedMatrix;
use crate::exec::{ArenaStats, ExecContext, KernelMode};
use crate::hist::{self, HistArena, Histogram};
use crate::quantile::{HistogramCuts, QuantizedMatrix};
use crate::tree::partitioner::BinSource;
use crate::tree::{RowPartitioner, SplitCandidate};
use crate::GradPair;

/// Pluggable executor for the histogram hot-spot. The native backend runs
/// the chunk-parallel Rust loop of [`crate::hist`]; the XLA backend
/// (`crate::runtime::XlaHistBackend`) feeds row tiles through the
/// AOT-compiled Pallas one-hot-matmul kernel.
///
/// The trait itself is deliberately **not** `Send`: the PJRT client
/// handle in the `xla` crate is `Rc`-based, so an XLA backend must stay
/// pinned to the one executor thread that owns it — the coordinator runs
/// its device loop serially on that thread. Backends that *can* execute
/// shards concurrently expose a `Send + Sync` view through
/// [`HistBackend::as_parallel`], which the coordinator uses to fan device
/// shards out across the [`ExecContext`] pool.
pub trait HistBackend {
    /// Accumulate the gradient histogram of `rows` into `out`
    /// (`out.n_bins()` == total bins). `exec` is the thread budget for
    /// chunk-level parallelism *within* this call; backends may ignore it.
    fn build_histogram(
        &mut self,
        shard: &DeviceShard,
        rows: &[u32],
        out: &mut Histogram,
        exec: &ExecContext,
    ) -> Result<()>;

    /// Human-readable name for logs / EXPERIMENTS.md.
    fn name(&self) -> &'static str;

    /// A thread-safe view of this backend for concurrent shard execution,
    /// or `None` if shards must run serially on the owning thread (the
    /// Rc-based XLA runtime). Default: `None`.
    fn as_parallel(&self) -> Option<&dyn ParallelHistBackend> {
        None
    }

    /// Read-and-reset the backend's round-arena counters (buffer-pool
    /// hits/misses/bytes reused since the last drain). Backends without
    /// an arena report zeros; the coordinator folds this into
    /// `BuildStats::{arena_allocs, arena_bytes_reused}` per tree.
    fn drain_arena_stats(&mut self) -> ArenaStats {
        ArenaStats::default()
    }
}

/// The `Send + Sync` half of the [`HistBackend`] split: backends whose
/// shard builds may run concurrently on pool workers. Implementations
/// must be stateless or internally synchronised.
pub trait ParallelHistBackend: Send + Sync {
    /// Same contract as [`HistBackend::build_histogram`], but callable
    /// from any worker thread through a shared reference.
    fn build_histogram_shard(
        &self,
        shard: &DeviceShard,
        rows: &[u32],
        out: &mut Histogram,
        exec: &ExecContext,
    ) -> Result<()>;
}

/// Pure-Rust histogram backend (also the `xgb-cpu-hist` baseline's
/// engine). Dispatches to the blocked, branchless kernels of
/// [`crate::hist`] by default (block symbol decode + null-scratch-slot
/// accumulation — see that module's docs); `XGB_SCALAR_KERNELS=1`
/// selects the row-at-a-time scalar reference. Both modes are
/// bit-identical, so the coordinator's determinism contract (same
/// result at every device count / thread count / page budget) is
/// unaffected by the kernel choice.
///
/// Owns the long-lived [`HistArena`]: per-chunk partials and blocked
/// decode scratch recycle across every histogram round of the training
/// run, so steady-state rounds allocate ~nothing in the hot loop. The
/// arena is internally synchronised (concurrent shard builds on pool
/// workers take/put through a mutex-guarded free list).
#[derive(Debug, Default, Clone)]
pub struct NativeBackend {
    arena: HistArena,
}

impl ParallelHistBackend for NativeBackend {
    fn build_histogram_shard(
        &self,
        shard: &DeviceShard,
        rows: &[u32],
        out: &mut Histogram,
        exec: &ExecContext,
    ) -> Result<()> {
        let mode = KernelMode::from_env();
        match &shard.storage {
            ShardStorage::Quantized(qm) => {
                hist::build_histogram_quantized_par_mode(
                    qm,
                    &shard.gradients,
                    rows,
                    out,
                    exec,
                    mode,
                    &self.arena,
                );
                Ok(())
            }
            ShardStorage::Compressed(cm) => {
                hist::build_histogram_compressed_par_mode(
                    cm,
                    &shard.gradients,
                    rows,
                    out,
                    exec,
                    mode,
                    &self.arena,
                );
                Ok(())
            }
            ShardStorage::Paged(ps) => hist::build_histogram_paged_mode(
                ps,
                &shard.gradients,
                rows,
                out,
                exec,
                mode,
                &self.arena,
            ),
        }
    }
}

impl HistBackend for NativeBackend {
    fn build_histogram(
        &mut self,
        shard: &DeviceShard,
        rows: &[u32],
        out: &mut Histogram,
        exec: &ExecContext,
    ) -> Result<()> {
        self.build_histogram_shard(shard, rows, out, exec)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn as_parallel(&self) -> Option<&dyn ParallelHistBackend> {
        Some(self)
    }

    fn drain_arena_stats(&mut self) -> ArenaStats {
        self.arena.drain_stats()
    }
}

/// Shard storage: raw u32 bins, bit-packed (§2.2), or bit-packed pages
/// spilled to a per-shard on-disk file and fetched per histogram round
/// (external memory; [`crate::compress::page`]).
#[derive(Debug)]
pub enum ShardStorage {
    Quantized(QuantizedMatrix),
    Compressed(CompressedMatrix),
    Paged(PageStore),
}

impl ShardStorage {
    pub fn n_rows(&self) -> usize {
        match self {
            ShardStorage::Quantized(q) => q.n_rows,
            ShardStorage::Compressed(c) => c.n_rows,
            ShardStorage::Paged(p) => p.n_rows(),
        }
    }

    pub fn n_bins(&self) -> usize {
        match self {
            ShardStorage::Quantized(q) => q.n_bins,
            ShardStorage::Compressed(c) => c.n_bins,
            ShardStorage::Paged(p) => p.shape.n_bins,
        }
    }

    pub fn row_stride(&self) -> usize {
        match self {
            ShardStorage::Quantized(q) => q.row_stride,
            ShardStorage::Compressed(c) => c.row_stride,
            ShardStorage::Paged(p) => p.shape.row_stride,
        }
    }

    /// Total bytes of the feature matrix on this device — the quantity
    /// behind the paper's "600 MB per GPU" claim. For a paged shard this
    /// is the *spilled* (on-disk) size; the resident share is bounded by
    /// the page budget and reported by [`ShardStorage::resident_bytes`].
    pub fn bytes(&self) -> usize {
        match self {
            ShardStorage::Quantized(q) => q.bytes(),
            ShardStorage::Compressed(c) => c.bytes(),
            ShardStorage::Paged(p) => p.spilled_bytes(),
        }
    }

    /// Bytes of the feature matrix currently held in host memory. Equals
    /// [`bytes`](Self::bytes) for resident storage; for a paged shard,
    /// the live page handles only (≤ `max_resident_pages × page_bytes`).
    pub fn resident_bytes(&self) -> usize {
        match self {
            ShardStorage::Paged(p) => p.resident_bytes(),
            other => other.bytes(),
        }
    }

    pub fn bin_source(&self) -> BinSource<'_> {
        match self {
            ShardStorage::Quantized(q) => BinSource::Quantized(q),
            ShardStorage::Compressed(c) => BinSource::Compressed(c),
            ShardStorage::Paged(p) => BinSource::Paged(p),
        }
    }

    /// Clone resident storage (test fixtures). Paged shards are not
    /// clonable: the spill file is uniquely owned by its store.
    pub fn clone_in_memory(&self) -> ShardStorage {
        match self {
            ShardStorage::Quantized(q) => ShardStorage::Quantized(q.clone()),
            ShardStorage::Compressed(c) => ShardStorage::Compressed(c.clone()),
            ShardStorage::Paged(_) => panic!("paged shard storage cannot be cloned"),
        }
    }
}

/// One simulated device and its local state.
pub struct DeviceShard {
    pub id: usize,
    /// Global row index of this shard's local row 0 (shards are
    /// contiguous).
    pub row_offset: usize,
    pub storage: ShardStorage,
    /// Per-local-row gradient pairs for the current boosting iteration.
    pub gradients: Vec<GradPair>,
    pub partitioner: RowPartitioner,
}

impl DeviceShard {
    pub fn new(id: usize, row_offset: usize, storage: ShardStorage) -> Self {
        let n = storage.n_rows();
        DeviceShard {
            id,
            row_offset,
            storage,
            gradients: Vec::new(),
            partitioner: RowPartitioner::new(n),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.storage.n_rows()
    }

    /// Install this iteration's gradients (slice of the global gradient
    /// vector covering `row_offset .. row_offset + n_rows`) and reset the
    /// partitioner for a fresh tree.
    pub fn begin_tree(&mut self, gradients: &[GradPair]) {
        debug_assert_eq!(gradients.len(), self.n_rows());
        self.gradients.clear();
        self.gradients.extend_from_slice(gradients);
        self.partitioner.reset(self.n_rows());
    }

    /// Shard-local gradient sum over all rows (root reduction input).
    pub fn local_sum(&self) -> (f64, f64) {
        let mut g = 0.0;
        let mut h = 0.0;
        for gp in &self.gradients {
            g += gp.grad as f64;
            h += gp.hess as f64;
        }
        (g, h)
    }

    /// `RepartitionInstances` for one applied split; returns local
    /// `(n_left, n_right)`. `exec` bounds chunk-level parallelism within
    /// this shard's repartition.
    pub fn repartition(
        &mut self,
        nid: usize,
        split: &SplitCandidate,
        left: usize,
        right: usize,
        cuts: &HistogramCuts,
        exec: &ExecContext,
    ) -> (usize, usize) {
        let src = self.storage.bin_source();
        self.partitioner
            .apply_split_par(nid, split, left, right, &src, cuts, exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;
    use crate::quantile::Quantizer;
    use crate::Float;

    fn make_shard(compress: bool) -> (DeviceShard, HistogramCuts) {
        let vals: Vec<Float> = (0..64).map(|i| (i % 16) as Float).collect();
        let x = DMatrix::dense(vals, 32, 2);
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let storage = if compress {
            ShardStorage::Compressed(crate::compress::CompressedMatrix::from_quantized(&qm))
        } else {
            ShardStorage::Quantized(qm)
        };
        let mut s = DeviceShard::new(0, 0, storage);
        let grads: Vec<GradPair> = (0..32)
            .map(|i| GradPair::new(i as f32 / 32.0 - 0.5, 1.0))
            .collect();
        s.begin_tree(&grads);
        (s, cuts)
    }

    #[test]
    fn local_sum_matches_direct() {
        let (s, _) = make_shard(false);
        let (g, h) = s.local_sum();
        let expect_g: f64 = (0..32).map(|i| i as f64 / 32.0 - 0.5).sum();
        assert!((g - expect_g).abs() < 1e-6);
        assert!((h - 32.0).abs() < 1e-9);
    }

    #[test]
    fn native_backend_same_result_compressed_or_not() {
        let (s1, _) = make_shard(false);
        let (s2, _) = make_shard(true);
        let rows: Vec<u32> = (0..32).collect();
        let mut h1 = Histogram::zeros(s1.storage.n_bins());
        let mut h2 = Histogram::zeros(s2.storage.n_bins());
        let mut be = NativeBackend::default();
        let exec = ExecContext::serial();
        be.build_histogram(&s1, &rows, &mut h1, &exec).unwrap();
        be.build_histogram(&s2, &rows, &mut h2, &exec).unwrap();
        assert_eq!(h1, h2);
    }

    #[test]
    fn begin_tree_resets_partitioner() {
        let (mut s, cuts) = make_shard(false);
        let split = SplitCandidate {
            feature: 0,
            split_bin: 2,
            threshold: 0.0,
            default_left: true,
            gain: 1.0,
            left_sum: Default::default(),
            right_sum: Default::default(),
            categories: 0,
            cat_bins: 0,
        };
        s.repartition(0, &split, 1, 2, &cuts, &ExecContext::serial());
        assert!(s.partitioner.node_count(1) > 0);
        let grads = s.gradients.clone();
        s.begin_tree(&grads);
        assert_eq!(s.partitioner.node_count(0), 32);
    }

    #[test]
    fn compressed_storage_is_smaller() {
        let (raw, _) = make_shard(false);
        let (packed, _) = make_shard(true);
        assert!(packed.storage.bytes() < raw.storage.bytes());
    }
}
