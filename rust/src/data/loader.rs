//! File loaders: CSV (dense) and LibSVM (sparse), the two formats the
//! paper's benchmark repository uses for its public datasets.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::{DMatrix, Dataset};
use crate::Float;

/// Load a CSV file into a dense [`Dataset`].
///
/// * `label_col` — index of the label column; all other columns are
///   features in order.
/// * `has_header` — skip the first line.
/// * empty fields and the literal strings `na`, `nan`, `?` (case
///   insensitive) become missing values.
pub fn load_csv(path: impl AsRef<Path>, label_col: usize, has_header: bool) -> Result<Dataset> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    parse_csv(BufReader::new(file), label_col, has_header)
}

/// CSV parser over any reader (unit-testable without files).
pub fn parse_csv(reader: impl Read, label_col: usize, has_header: bool) -> Result<Dataset> {
    let reader = BufReader::new(reader);
    let mut values: Vec<Float> = Vec::new();
    let mut labels: Vec<Float> = Vec::new();
    let mut n_cols_file: Option<usize> = None;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading csv line")?;
        if lineno == 0 && has_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        match n_cols_file {
            None => {
                if label_col >= fields.len() {
                    bail!("label column {label_col} out of range ({} fields)", fields.len());
                }
                n_cols_file = Some(fields.len());
            }
            Some(n) if n != fields.len() => {
                bail!("line {}: expected {} fields, got {}", lineno + 1, n, fields.len())
            }
            _ => {}
        }
        for (i, f) in fields.iter().enumerate() {
            let v = parse_field(f)
                .with_context(|| format!("line {} field {}: {:?}", lineno + 1, i, f))?;
            if i == label_col {
                if v.is_nan() {
                    bail!("line {}: missing label", lineno + 1);
                }
                labels.push(v);
            } else {
                values.push(v);
            }
        }
    }
    let n_rows = labels.len();
    let n_cols = n_cols_file.map(|n| n - 1).unwrap_or(0);
    Ok(Dataset::new(DMatrix::dense(values, n_rows, n_cols), labels))
}

fn parse_field(f: &str) -> Result<Float> {
    let t = f.trim();
    if t.is_empty() || t.eq_ignore_ascii_case("na") || t.eq_ignore_ascii_case("nan") || t == "?" {
        return Ok(Float::NAN);
    }
    t.parse::<Float>()
        .map_err(|e| anyhow::anyhow!("bad number: {e}"))
}

/// Load a LibSVM-format file (`label idx:val idx:val ...`, 0- or 1-based
/// indices autodetected) into a sparse [`Dataset`]. Optional
/// `qid:<group>` tokens populate ranking groups.
pub fn load_libsvm(path: impl AsRef<Path>) -> Result<Dataset> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    parse_libsvm(BufReader::new(file))
}

/// LibSVM parser over any reader.
pub fn parse_libsvm(reader: impl Read) -> Result<Dataset> {
    let reader = BufReader::new(reader);
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<Float> = Vec::new();
    let mut labels: Vec<Float> = Vec::new();
    let mut qids: Vec<i64> = Vec::new();
    let mut max_col: u32 = 0;
    let mut min_col: u32 = u32::MAX;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading libsvm line")?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut tokens = line.split_ascii_whitespace();
        let label: Float = tokens
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        labels.push(label);
        let mut row: Vec<(u32, Float)> = Vec::new();
        let mut qid: i64 = -1;
        for tok in tokens {
            let colon = tok
                .find(':')
                .with_context(|| format!("line {}: token {:?} missing ':'", lineno + 1, tok))?;
            let (k, v) = tok.split_at(colon);
            let v = &v[1..];
            if k == "qid" {
                qid = v
                    .parse()
                    .with_context(|| format!("line {}: bad qid", lineno + 1))?;
                continue;
            }
            let col: u32 = k
                .parse()
                .with_context(|| format!("line {}: bad index {:?}", lineno + 1, k))?;
            let val: Float = v
                .parse()
                .with_context(|| format!("line {}: bad value {:?}", lineno + 1, v))?;
            max_col = max_col.max(col);
            min_col = min_col.min(col);
            row.push((col, val));
        }
        qids.push(qid);
        row.sort_unstable_by_key(|&(c, _)| c);
        for (c, v) in row {
            indices.push(c);
            values.push(v);
        }
        indptr.push(indices.len());
    }

    // 1-based index files never use column 0.
    let one_based = !indices.is_empty() && min_col >= 1;
    if one_based {
        for c in indices.iter_mut() {
            *c -= 1;
        }
        max_col -= 1;
    }
    let n_rows = labels.len();
    let n_cols = if indices.is_empty() { 0 } else { max_col as usize + 1 };

    // Build group boundaries from contiguous qid runs, if any were present.
    let mut groups = Vec::new();
    if qids.iter().any(|&q| q >= 0) {
        if qids.iter().any(|&q| q < 0) {
            bail!("mixed qid / non-qid rows");
        }
        groups.push(0);
        for i in 1..qids.len() {
            if qids[i] != qids[i - 1] {
                groups.push(i);
            }
        }
        groups.push(qids.len());
    }

    let x = DMatrix::csr(indptr, indices, values, n_rows, n_cols);
    Ok(if groups.is_empty() {
        Dataset::new(x, labels)
    } else {
        Dataset::with_groups(x, labels, groups)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_basic() {
        let data = "y,f1,f2\n1,0.5,2.0\n0,,3.5\n";
        let ds = parse_csv(data.as_bytes(), 0, true).unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(ds.n_cols(), 2);
        assert_eq!(ds.y, vec![1.0, 0.0]);
        assert_eq!(ds.x.get(0, 0), Some(0.5));
        assert_eq!(ds.x.get(1, 0), None); // empty field -> missing
        assert_eq!(ds.x.get(1, 1), Some(3.5));
    }

    #[test]
    fn csv_label_not_first() {
        let data = "1.0,2.0,5\n3.0,4.0,6\n";
        let ds = parse_csv(data.as_bytes(), 2, false).unwrap();
        assert_eq!(ds.y, vec![5.0, 6.0]);
        assert_eq!(ds.x.get(1, 1), Some(4.0));
    }

    #[test]
    fn csv_na_tokens() {
        let data = "0,NA,nan,?\n";
        let ds = parse_csv(data.as_bytes(), 0, false).unwrap();
        assert_eq!(ds.x.nnz(), 0);
    }

    #[test]
    fn csv_ragged_is_error() {
        let data = "0,1,2\n1,2\n";
        assert!(parse_csv(data.as_bytes(), 0, false).is_err());
    }

    #[test]
    fn csv_missing_label_is_error() {
        let data = ",1,2\n";
        assert!(parse_csv(data.as_bytes(), 0, false).is_err());
    }

    #[test]
    fn libsvm_basic_one_based() {
        let data = "1 1:0.5 3:1.5\n0 2:2.5\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(ds.n_cols(), 3);
        assert_eq!(ds.x.get(0, 0), Some(0.5));
        assert_eq!(ds.x.get(0, 2), Some(1.5));
        assert_eq!(ds.x.get(0, 1), None);
        assert_eq!(ds.x.get(1, 1), Some(2.5));
    }

    #[test]
    fn libsvm_zero_based() {
        let data = "1 0:1.0\n0 4:2.0\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.n_cols(), 5);
        assert_eq!(ds.x.get(0, 0), Some(1.0));
    }

    #[test]
    fn libsvm_qid_groups() {
        let data = "2 qid:1 1:1.0\n1 qid:1 1:0.5\n0 qid:2 1:0.1\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.groups, vec![0, 2, 3]);
    }

    #[test]
    fn libsvm_comments_and_blank_lines() {
        let data = "# header\n1 1:2.0 # trailing\n\n0 1:3.0\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.n_rows(), 2);
    }

    #[test]
    fn libsvm_unsorted_indices_ok() {
        let data = "1 3:3.0 1:1.0 2:2.0\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        let row: Vec<_> = ds.x.iter_row(0).collect();
        assert_eq!(row, vec![(0, 1.0), (1, 2.0), (2, 3.0)]);
    }

    #[test]
    fn libsvm_bad_token_is_error() {
        assert!(parse_libsvm("1 nocolon\n".as_bytes()).is_err());
        assert!(parse_libsvm("1 a:1.0\n".as_bytes()).is_err());
    }
}
