//! File loaders and writers: CSV (dense) and LibSVM (sparse), the two
//! formats the paper's benchmark repository uses for its public datasets.
//!
//! The per-line parsers ([`CsvLineParser`], [`parse_libsvm_line`]) are
//! shared with the streaming [`crate::data::source`] readers, so the
//! in-memory and out-of-core ingestion paths see byte-for-byte identical
//! values — the precondition for the bit-identity contract between
//! `Learner::train` and `Learner::train_from_source`.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::{DMatrix, Dataset};
use crate::Float;

/// Load a CSV file into a dense [`Dataset`].
///
/// * `label_col` — index of the label column; all other columns are
///   features in order.
/// * `has_header` — skip the first line.
/// * empty fields and the literal strings `na`, `nan`, `?` (case
///   insensitive) become missing values.
pub fn load_csv(path: impl AsRef<Path>, label_col: usize, has_header: bool) -> Result<Dataset> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    parse_csv(BufReader::new(file), label_col, has_header)
}

/// Stateful CSV line parser: tracks the field count of the first data line
/// and rejects ragged rows. One instance per file pass (the streaming
/// reader keeps it across batches).
#[derive(Debug, Clone)]
pub(crate) struct CsvLineParser {
    pub label_col: usize,
    /// Fields per line, fixed by the first data line.
    pub n_fields: Option<usize>,
}

impl CsvLineParser {
    pub fn new(label_col: usize) -> Self {
        CsvLineParser {
            label_col,
            n_fields: None,
        }
    }

    /// Feature count (known after the first data line).
    pub fn n_cols(&self) -> Option<usize> {
        self.n_fields.map(|n| n - 1)
    }

    /// Parse one data line, pushing its feature values (NaN = missing)
    /// onto `features` and returning the label. Blank lines return
    /// `Ok(None)` and push nothing. `lineno` is 0-based.
    pub fn parse_line(
        &mut self,
        line: &str,
        lineno: usize,
        features: &mut Vec<Float>,
    ) -> Result<Option<Float>> {
        let line = line.trim();
        if line.is_empty() {
            return Ok(None);
        }
        let fields: Vec<&str> = line.split(',').collect();
        match self.n_fields {
            None => {
                if self.label_col >= fields.len() {
                    bail!(
                        "label column {} out of range ({} fields)",
                        self.label_col,
                        fields.len()
                    );
                }
                self.n_fields = Some(fields.len());
            }
            Some(n) if n != fields.len() => {
                bail!("line {}: expected {} fields, got {}", lineno + 1, n, fields.len())
            }
            _ => {}
        }
        let mut label = 0.0;
        for (i, f) in fields.iter().enumerate() {
            let v = parse_field(f)
                .with_context(|| format!("line {} field {}: {:?}", lineno + 1, i, f))?;
            if i == self.label_col {
                if v.is_nan() {
                    bail!("line {}: missing label", lineno + 1);
                }
                label = v;
            } else {
                features.push(v);
            }
        }
        Ok(Some(label))
    }
}

/// CSV parser over any reader (unit-testable without files).
pub fn parse_csv(reader: impl Read, label_col: usize, has_header: bool) -> Result<Dataset> {
    let reader = BufReader::new(reader);
    let mut parser = CsvLineParser::new(label_col);
    let mut values: Vec<Float> = Vec::new();
    let mut labels: Vec<Float> = Vec::new();

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading csv line")?;
        if lineno == 0 && has_header {
            continue;
        }
        if let Some(label) = parser.parse_line(&line, lineno, &mut values)? {
            labels.push(label);
        }
    }
    let n_rows = labels.len();
    let n_cols = parser.n_cols().unwrap_or(0);
    Ok(Dataset::new(DMatrix::dense(values, n_rows, n_cols), labels))
}

/// Which feature columns a CSV header flags as categorical: a header cell
/// spelled `cat:<name>` marks its column. Returned indices are in
/// **feature** space (the label column removed), ready for
/// `LearnerParams::categorical_features`. A headerless or tag-free file
/// yields an empty list.
pub fn csv_header_categoricals(path: impl AsRef<Path>, label_col: usize) -> Result<Vec<usize>> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut header = String::new();
    BufReader::new(file)
        .read_line(&mut header)
        .context("reading csv header")?;
    let mut cats = Vec::new();
    let mut feature = 0usize;
    for (i, cell) in header.trim().split(',').enumerate() {
        if i == label_col {
            continue;
        }
        if cell.trim().starts_with("cat:") {
            cats.push(feature);
        }
        feature += 1;
    }
    Ok(cats)
}

fn parse_field(f: &str) -> Result<Float> {
    let t = f.trim();
    if t.is_empty() || t.eq_ignore_ascii_case("na") || t.eq_ignore_ascii_case("nan") || t == "?" {
        return Ok(Float::NAN);
    }
    t.parse::<Float>()
        .map_err(|e| anyhow::anyhow!("bad number: {e}"))
}

/// One parsed LibSVM row: label, optional qid (−1 = absent), and the
/// `(column, value)` pairs sorted ascending by column. Column indices are
/// **raw** (as written in the file); 0- vs 1-based resolution needs the
/// whole file and is done by the caller.
pub(crate) struct LibsvmRow {
    pub label: Float,
    pub qid: i64,
    pub pairs: Vec<(u32, Float)>,
}

/// Parse one LibSVM line (`label [qid:g] idx:val ...`). Comments (`#`)
/// are stripped; blank lines return `Ok(None)`.
///
/// Duplicate feature indices within a row keep the **last** occurrence
/// (XGBoost convention) — without the dedup they would survive the sort
/// and produce an invalid CSR row.
pub(crate) fn parse_libsvm_line(line: &str, lineno: usize) -> Result<Option<LibsvmRow>> {
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let mut tokens = line.split_ascii_whitespace();
    let label: Float = tokens
        .next()
        .unwrap()
        .parse()
        .with_context(|| format!("line {}: bad label", lineno + 1))?;
    let mut pairs: Vec<(u32, Float)> = Vec::new();
    let mut qid: i64 = -1;
    for tok in tokens {
        let colon = tok
            .find(':')
            .with_context(|| format!("line {}: token {:?} missing ':'", lineno + 1, tok))?;
        let (k, v) = tok.split_at(colon);
        let v = &v[1..];
        if k == "qid" {
            qid = v
                .parse()
                .with_context(|| format!("line {}: bad qid", lineno + 1))?;
            continue;
        }
        let col: u32 = k
            .parse()
            .with_context(|| format!("line {}: bad index {:?}", lineno + 1, k))?;
        let val: Float = v
            .parse()
            .with_context(|| format!("line {}: bad value {:?}", lineno + 1, v))?;
        pairs.push((col, val));
    }
    // stable sort, then collapse duplicate columns keeping the last-written
    // value: within an equal-key run the stable sort preserves file order,
    // so the run's final element is the last occurrence.
    pairs.sort_by_key(|&(c, _)| c);
    let mut w = 0usize;
    for i in 0..pairs.len() {
        if w > 0 && pairs[w - 1].0 == pairs[i].0 {
            pairs[w - 1] = pairs[i];
        } else {
            pairs[w] = pairs[i];
            w += 1;
        }
    }
    pairs.truncate(w);
    Ok(Some(LibsvmRow { label, qid, pairs }))
}

/// Build query-group boundaries from per-row qids (−1 = absent). Groups
/// are contiguous qid runs, exactly as the in-memory loader defines them;
/// mixing qid and non-qid rows is an error. Returns an empty vector when
/// no row carried a qid.
pub(crate) fn groups_from_qids(qids: &[i64]) -> Result<Vec<usize>> {
    let mut groups = Vec::new();
    if qids.iter().any(|&q| q >= 0) {
        if qids.iter().any(|&q| q < 0) {
            bail!("mixed qid / non-qid rows");
        }
        groups.push(0);
        for i in 1..qids.len() {
            if qids[i] != qids[i - 1] {
                groups.push(i);
            }
        }
        groups.push(qids.len());
    }
    Ok(groups)
}

/// Load a LibSVM-format file (`label idx:val idx:val ...`, 0- or 1-based
/// indices autodetected) into a sparse [`Dataset`]. Optional
/// `qid:<group>` tokens populate ranking groups.
pub fn load_libsvm(path: impl AsRef<Path>) -> Result<Dataset> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    parse_libsvm(BufReader::new(file))
}

/// LibSVM parser over any reader.
pub fn parse_libsvm(reader: impl Read) -> Result<Dataset> {
    let reader = BufReader::new(reader);
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<Float> = Vec::new();
    let mut labels: Vec<Float> = Vec::new();
    let mut qids: Vec<i64> = Vec::new();
    let mut max_col: u32 = 0;
    let mut min_col: u32 = u32::MAX;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading libsvm line")?;
        let Some(row) = parse_libsvm_line(&line, lineno)? else {
            continue;
        };
        labels.push(row.label);
        qids.push(row.qid);
        for (c, v) in row.pairs {
            max_col = max_col.max(c);
            min_col = min_col.min(c);
            indices.push(c);
            values.push(v);
        }
        indptr.push(indices.len());
    }

    // 1-based index files never use column 0.
    let one_based = !indices.is_empty() && min_col >= 1;
    if one_based {
        for c in indices.iter_mut() {
            *c -= 1;
        }
        max_col -= 1;
    }
    let n_rows = labels.len();
    let n_cols = if indices.is_empty() { 0 } else { max_col as usize + 1 };

    let groups = groups_from_qids(&qids)?;
    let x = DMatrix::csr(indptr, indices, values, n_rows, n_cols);
    Ok(if groups.is_empty() {
        Dataset::new(x, labels)
    } else {
        Dataset::with_groups(x, labels, groups)
    })
}

/// Write a dataset as CSV with the label in column 0 and missing values as
/// empty fields — the inverse of [`load_csv`] with `label_col = 0`,
/// `has_header = false`. Values print in Rust's shortest round-trip form,
/// so `load_csv(save_csv(ds))` reproduces every float bit-for-bit.
pub fn save_csv(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    use std::io::Write as _;
    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut out = std::io::BufWriter::new(file);
    let n_cols = ds.n_cols();
    for r in 0..ds.n_rows() {
        let mut line = String::with_capacity(n_cols * 8 + 8);
        line.push_str(&format!("{}", ds.y[r]));
        let mut row = vec![Float::NAN; n_cols];
        for (c, v) in ds.x.iter_row(r) {
            row[c] = v;
        }
        for v in row {
            line.push(',');
            if !v.is_nan() {
                line.push_str(&format!("{v}"));
            }
        }
        line.push('\n');
        out.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// Write a dataset in LibSVM format with 1-based column indices (absent
/// entries are omitted). Ranking groups, when present, are emitted as
/// `qid:<group-index>` tokens. `load_libsvm(save_libsvm(ds))` reproduces
/// values and groups exactly.
pub fn save_libsvm(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    use std::io::Write as _;
    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut out = std::io::BufWriter::new(file);
    let mut group = 0usize;
    for r in 0..ds.n_rows() {
        let mut line = String::with_capacity(32);
        line.push_str(&format!("{}", ds.y[r]));
        if !ds.groups.is_empty() {
            while r >= ds.groups[group + 1] {
                group += 1;
            }
            line.push_str(&format!(" qid:{group}"));
        }
        for (c, v) in ds.x.iter_row(r) {
            line.push_str(&format!(" {}:{}", c + 1, v));
        }
        line.push('\n');
        out.write_all(line.as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_basic() {
        let data = "y,f1,f2\n1,0.5,2.0\n0,,3.5\n";
        let ds = parse_csv(data.as_bytes(), 0, true).unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(ds.n_cols(), 2);
        assert_eq!(ds.y, vec![1.0, 0.0]);
        assert_eq!(ds.x.get(0, 0), Some(0.5));
        assert_eq!(ds.x.get(1, 0), None); // empty field -> missing
        assert_eq!(ds.x.get(1, 1), Some(3.5));
    }

    #[test]
    fn csv_label_not_first() {
        let data = "1.0,2.0,5\n3.0,4.0,6\n";
        let ds = parse_csv(data.as_bytes(), 2, false).unwrap();
        assert_eq!(ds.y, vec![5.0, 6.0]);
        assert_eq!(ds.x.get(1, 1), Some(4.0));
    }

    #[test]
    fn csv_na_tokens() {
        let data = "0,NA,nan,?\n";
        let ds = parse_csv(data.as_bytes(), 0, false).unwrap();
        assert_eq!(ds.x.nnz(), 0);
    }

    #[test]
    fn csv_ragged_is_error() {
        let data = "0,1,2\n1,2\n";
        assert!(parse_csv(data.as_bytes(), 0, false).is_err());
    }

    #[test]
    fn csv_missing_label_is_error() {
        let data = ",1,2\n";
        assert!(parse_csv(data.as_bytes(), 0, false).is_err());
    }

    #[test]
    fn libsvm_basic_one_based() {
        let data = "1 1:0.5 3:1.5\n0 2:2.5\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(ds.n_cols(), 3);
        assert_eq!(ds.x.get(0, 0), Some(0.5));
        assert_eq!(ds.x.get(0, 2), Some(1.5));
        assert_eq!(ds.x.get(0, 1), None);
        assert_eq!(ds.x.get(1, 1), Some(2.5));
    }

    #[test]
    fn libsvm_zero_based() {
        let data = "1 0:1.0\n0 4:2.0\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.n_cols(), 5);
        assert_eq!(ds.x.get(0, 0), Some(1.0));
    }

    #[test]
    fn libsvm_qid_groups() {
        let data = "2 qid:1 1:1.0\n1 qid:1 1:0.5\n0 qid:2 1:0.1\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.groups, vec![0, 2, 3]);
    }

    #[test]
    fn libsvm_comments_and_blank_lines() {
        let data = "# header\n1 1:2.0 # trailing\n\n0 1:3.0\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        assert_eq!(ds.n_rows(), 2);
    }

    #[test]
    fn libsvm_unsorted_indices_ok() {
        let data = "1 3:3.0 1:1.0 2:2.0\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        let row: Vec<_> = ds.x.iter_row(0).collect();
        assert_eq!(row, vec![(0, 1.0), (1, 2.0), (2, 3.0)]);
    }

    #[test]
    fn libsvm_duplicate_indices_keep_last() {
        // regression: duplicates used to survive the sort, producing a CSR
        // row with repeated column indices (invalid — `get`'s binary
        // search and the quantizer both assume strictly ascending columns)
        let data = "1 2:9.0 1:1.0 2:5.0 2:7.0\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        let row: Vec<_> = ds.x.iter_row(0).collect();
        assert_eq!(row, vec![(0, 1.0), (1, 7.0)], "last occurrence wins");
        assert_eq!(ds.x.get(0, 1), Some(7.0));
        assert_eq!(ds.x.nnz(), 2);
    }

    #[test]
    fn libsvm_bad_token_is_error() {
        assert!(parse_libsvm("1 nocolon\n".as_bytes()).is_err());
        assert!(parse_libsvm("1 a:1.0\n".as_bytes()).is_err());
    }

    #[test]
    fn csv_header_cat_tags_map_to_feature_indices() {
        // label in column 1: feature indices skip over it
        let data = "f_a,y,cat:color,f_b,cat:size\n0.5,1,3,0.25,7\n";
        let path = std::env::temp_dir().join("xgb_tpu_loader_cat_header.csv");
        std::fs::write(&path, data).unwrap();
        let cats = csv_header_categoricals(&path, 1).unwrap();
        assert_eq!(cats, vec![1, 3], "feature space, label column removed");
        // no tags -> empty
        let plain = "y,f1,f2\n1,2,3\n";
        std::fs::write(&path, plain).unwrap();
        assert!(csv_header_categoricals(&path, 0).unwrap().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_save_load_round_trip() {
        let data = "1,0.5,,2.25\n0,-3.5,0.125,\n";
        let ds = parse_csv(data.as_bytes(), 0, false).unwrap();
        let path = std::env::temp_dir().join("xgb_tpu_loader_csv_rt.csv");
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path, 0, false).unwrap();
        assert_eq!(back.y, ds.y);
        for r in 0..ds.n_rows() {
            for c in 0..ds.n_cols() {
                assert_eq!(back.x.get(r, c), ds.x.get(r, c), "({r},{c})");
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn libsvm_save_load_round_trip_with_groups() {
        let data = "2 qid:7 1:1.5 3:-0.25\n1 qid:7 2:0.75\n0 qid:9 1:0.1\n";
        let ds = parse_libsvm(data.as_bytes()).unwrap();
        let path = std::env::temp_dir().join("xgb_tpu_loader_libsvm_rt.libsvm");
        save_libsvm(&ds, &path).unwrap();
        let back = load_libsvm(&path).unwrap();
        assert_eq!(back.y, ds.y);
        assert_eq!(back.groups, ds.groups);
        assert_eq!(back.n_cols(), ds.n_cols());
        for r in 0..ds.n_rows() {
            let a: Vec<_> = ds.x.iter_row(r).collect();
            let b: Vec<_> = back.x.iter_row(r).collect();
            assert_eq!(a, b, "row {r}");
        }
        let _ = std::fs::remove_file(&path);
    }
}
