//! Training data substrate: dense/sparse matrices, file loaders and
//! writers, the streaming [`source::BatchSource`] ingestion front end, and
//! the synthetic dataset registry that stands in for the paper's six
//! public datasets (Table 1) in this offline environment.

pub mod dmatrix;
pub mod loader;
pub mod source;
pub mod synthetic;

pub use dmatrix::{DMatrix, Dataset};
pub use loader::{csv_header_categoricals, load_csv, load_libsvm, save_csv, save_libsvm};
pub use source::{
    scan_source, scan_source_meta, scan_source_with_categories, BatchSource, CsvSource,
    DMatrixSource, IngestMeta, LibsvmSource, RowBatch, SyntheticSource, DEFAULT_BATCH_ROWS,
};
