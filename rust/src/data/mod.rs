//! Training data substrate: dense/sparse matrices, file loaders, and the
//! synthetic dataset registry that stands in for the paper's six public
//! datasets (Table 1) in this offline environment.

pub mod dmatrix;
pub mod loader;
pub mod synthetic;

pub use dmatrix::{DMatrix, Dataset};
pub use loader::{load_csv, load_libsvm};
