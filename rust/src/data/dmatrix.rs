//! `DMatrix`: the input feature matrix, in either dense row-major or CSR
//! sparse form, with NaN denoting missing values (XGBoost convention).
//!
//! All downstream stages (quantile sketch, quantisation, compression) read
//! through the [`DMatrix::iter_row`] / [`DMatrix::for_each_in_column`]
//! accessors so dense and sparse inputs share one code path.

use crate::Float;

/// Feature matrix. Missing entries are `NaN` in dense form, absent in CSR.
#[derive(Debug, Clone)]
pub enum DMatrix {
    /// Row-major dense: `values[row * n_cols + col]`.
    Dense {
        values: Vec<Float>,
        n_rows: usize,
        n_cols: usize,
    },
    /// CSR sparse.
    Csr {
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<Float>,
        n_rows: usize,
        n_cols: usize,
    },
}

impl DMatrix {
    /// Build a dense matrix from a row-major buffer.
    pub fn dense(values: Vec<Float>, n_rows: usize, n_cols: usize) -> Self {
        assert_eq!(values.len(), n_rows * n_cols, "dense shape mismatch");
        DMatrix::Dense {
            values,
            n_rows,
            n_cols,
        }
    }

    /// Build a CSR matrix. `indptr.len() == n_rows + 1`.
    pub fn csr(
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<Float>,
        n_rows: usize,
        n_cols: usize,
    ) -> Self {
        assert_eq!(indptr.len(), n_rows + 1, "csr indptr length");
        assert_eq!(indices.len(), values.len(), "csr nnz mismatch");
        assert_eq!(*indptr.last().unwrap(), values.len(), "csr indptr tail");
        debug_assert!(indices.iter().all(|&c| (c as usize) < n_cols));
        DMatrix::Csr {
            indptr,
            indices,
            values,
            n_rows,
            n_cols,
        }
    }

    pub fn n_rows(&self) -> usize {
        match self {
            DMatrix::Dense { n_rows, .. } | DMatrix::Csr { n_rows, .. } => *n_rows,
        }
    }

    pub fn n_cols(&self) -> usize {
        match self {
            DMatrix::Dense { n_cols, .. } | DMatrix::Csr { n_cols, .. } => *n_cols,
        }
    }

    /// Number of stored (present, non-NaN) entries.
    pub fn nnz(&self) -> usize {
        match self {
            DMatrix::Dense { values, .. } => values.iter().filter(|v| !v.is_nan()).count(),
            DMatrix::Csr { values, .. } => values.len(),
        }
    }

    /// Density of present values in `[0, 1]`.
    pub fn density(&self) -> f64 {
        let total = self.n_rows() * self.n_cols();
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Value at `(row, col)`; `None` if missing.
    pub fn get(&self, row: usize, col: usize) -> Option<Float> {
        match self {
            DMatrix::Dense { values, n_cols, .. } => {
                let v = values[row * n_cols + col];
                if v.is_nan() {
                    None
                } else {
                    Some(v)
                }
            }
            DMatrix::Csr {
                indptr,
                indices,
                values,
                ..
            } => {
                let (lo, hi) = (indptr[row], indptr[row + 1]);
                indices[lo..hi]
                    .binary_search(&(col as u32))
                    .ok()
                    .map(|i| values[lo + i])
            }
        }
    }

    /// Iterate present `(col, value)` pairs of one row.
    pub fn iter_row(&self, row: usize) -> RowIter<'_> {
        match self {
            DMatrix::Dense { values, n_cols, .. } => RowIter::Dense {
                slice: &values[row * n_cols..(row + 1) * n_cols],
                col: 0,
            },
            DMatrix::Csr {
                indptr,
                indices,
                values,
                ..
            } => RowIter::Csr {
                indices: &indices[indptr[row]..indptr[row + 1]],
                values: &values[indptr[row]..indptr[row + 1]],
                pos: 0,
            },
        }
    }

    /// Visit every present value of a column as `(row, value)`.
    /// Dense: O(n_rows); CSR: O(nnz) full scan — callers that need repeated
    /// column access should construct a [`ColumnView`] once instead.
    pub fn for_each_in_column(&self, col: usize, mut f: impl FnMut(usize, Float)) {
        match self {
            DMatrix::Dense {
                values,
                n_rows,
                n_cols,
            } => {
                for row in 0..*n_rows {
                    let v = values[row * n_cols + col];
                    if !v.is_nan() {
                        f(row, v);
                    }
                }
            }
            DMatrix::Csr {
                indptr,
                indices,
                values,
                n_rows,
                ..
            } => {
                for row in 0..*n_rows {
                    let (lo, hi) = (indptr[row], indptr[row + 1]);
                    if let Ok(i) = indices[lo..hi].binary_search(&(col as u32)) {
                        f(row, values[lo + i]);
                    }
                }
            }
        }
    }

    /// Take a subset of rows (used to shard the training set over devices
    /// and for train/validation splitting).
    pub fn take_rows(&self, rows: &[usize]) -> DMatrix {
        match self {
            DMatrix::Dense {
                values, n_cols, ..
            } => {
                let mut out = Vec::with_capacity(rows.len() * n_cols);
                for &r in rows {
                    out.extend_from_slice(&values[r * n_cols..(r + 1) * n_cols]);
                }
                DMatrix::dense(out, rows.len(), *n_cols)
            }
            DMatrix::Csr {
                indptr,
                indices,
                values,
                n_cols,
                ..
            } => {
                let mut new_indptr = Vec::with_capacity(rows.len() + 1);
                let mut new_indices = Vec::new();
                let mut new_values = Vec::new();
                new_indptr.push(0usize);
                for &r in rows {
                    let (lo, hi) = (indptr[r], indptr[r + 1]);
                    new_indices.extend_from_slice(&indices[lo..hi]);
                    new_values.extend_from_slice(&values[lo..hi]);
                    new_indptr.push(new_indices.len());
                }
                DMatrix::csr(new_indptr, new_indices, new_values, rows.len(), *n_cols)
            }
        }
    }

    /// Convert to dense (NaN-filled). Used by the XLA prediction path whose
    /// AOT artifact has a dense input signature.
    pub fn to_dense(&self) -> DMatrix {
        match self {
            DMatrix::Dense { .. } => self.clone(),
            DMatrix::Csr {
                indptr,
                indices,
                values,
                n_rows,
                n_cols,
            } => {
                let mut out = vec![Float::NAN; n_rows * n_cols];
                for row in 0..*n_rows {
                    for i in indptr[row]..indptr[row + 1] {
                        out[row * n_cols + indices[i] as usize] = values[i];
                    }
                }
                DMatrix::dense(out, *n_rows, *n_cols)
            }
        }
    }

    /// In-memory size of the raw float representation, in bytes — the
    /// baseline against which the paper's compression factor (§2.2) is
    /// measured.
    pub fn float_bytes(&self) -> usize {
        match self {
            DMatrix::Dense { values, .. } => values.len() * std::mem::size_of::<Float>(),
            DMatrix::Csr {
                indices, values, indptr, ..
            } => {
                values.len() * std::mem::size_of::<Float>()
                    + indices.len() * std::mem::size_of::<u32>()
                    + indptr.len() * std::mem::size_of::<usize>()
            }
        }
    }
}

/// Iterator over present `(col, value)` pairs of one row.
pub enum RowIter<'a> {
    Dense { slice: &'a [Float], col: usize },
    Csr {
        indices: &'a [u32],
        values: &'a [Float],
        pos: usize,
    },
}

impl<'a> Iterator for RowIter<'a> {
    type Item = (usize, Float);

    fn next(&mut self) -> Option<(usize, Float)> {
        match self {
            RowIter::Dense { slice, col } => {
                while *col < slice.len() {
                    let c = *col;
                    *col += 1;
                    if !slice[c].is_nan() {
                        return Some((c, slice[c]));
                    }
                }
                None
            }
            RowIter::Csr {
                indices,
                values,
                pos,
            } => {
                if *pos < indices.len() {
                    let p = *pos;
                    *pos += 1;
                    Some((indices[p] as usize, values[p]))
                } else {
                    None
                }
            }
        }
    }
}

/// A labelled dataset: features + labels (+ optional ranking groups or
/// survival interval upper bounds).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: DMatrix,
    pub y: Vec<Float>,
    /// Query-group boundaries for ranking tasks (`rank:pairwise`): group `g`
    /// spans rows `groups[g]..groups[g+1]`. Empty for non-ranking tasks.
    pub groups: Vec<usize>,
    /// Per-row upper interval bounds for survival tasks (`survival:aft`):
    /// `y` holds the lower bounds, this the uppers (`+∞` = right-censored,
    /// equal to `y` = uncensored event). Empty for non-survival tasks —
    /// [`Dataset::bounds_upper`] then reports `y` itself (every row an
    /// uncensored event).
    pub y_upper: Vec<Float>,
}

impl Dataset {
    pub fn new(x: DMatrix, y: Vec<Float>) -> Self {
        assert_eq!(x.n_rows(), y.len(), "labels/rows mismatch");
        Dataset {
            x,
            y,
            groups: Vec::new(),
            y_upper: Vec::new(),
        }
    }

    pub fn with_groups(x: DMatrix, y: Vec<Float>, groups: Vec<usize>) -> Self {
        assert_eq!(x.n_rows(), y.len(), "labels/rows mismatch");
        if !groups.is_empty() {
            assert_eq!(groups[0], 0);
            assert_eq!(*groups.last().unwrap(), y.len());
            assert!(groups.windows(2).all(|w| w[0] < w[1]));
        }
        Dataset {
            x,
            y,
            groups,
            y_upper: Vec::new(),
        }
    }

    /// Survival dataset: `y` lower and `y_upper` upper interval bounds
    /// (see the field docs for the censoring conventions).
    pub fn with_bounds(x: DMatrix, y: Vec<Float>, y_upper: Vec<Float>) -> Self {
        assert_eq!(x.n_rows(), y.len(), "labels/rows mismatch");
        assert_eq!(y.len(), y_upper.len(), "bounds/labels mismatch");
        debug_assert!(
            y.iter().zip(y_upper.iter()).all(|(&lo, &up)| lo <= up),
            "interval lower bounds must not exceed uppers"
        );
        Dataset {
            x,
            y,
            groups: Vec::new(),
            y_upper,
        }
    }

    /// Upper interval bounds: `y_upper` when present, else `y` itself
    /// (every label an exact, uncensored observation).
    pub fn bounds_upper(&self) -> &[Float] {
        if self.y_upper.is_empty() {
            &self.y
        } else {
            &self.y_upper
        }
    }

    pub fn n_rows(&self) -> usize {
        self.x.n_rows()
    }

    pub fn n_cols(&self) -> usize {
        self.x.n_cols()
    }

    /// Split into `(train, valid)` with `valid_frac` of rows held out,
    /// deterministically shuffled by `seed`.
    pub fn split(&self, valid_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let n = self.n_rows();
        let n_valid = ((n as f64) * valid_frac).round() as usize;
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = crate::util::Pcg64::new(seed);
        rng.shuffle(&mut idx);
        let (valid_idx, train_idx) = idx.split_at(n_valid);
        let take = |rows: &[usize]| {
            let mut d = Dataset::new(
                self.x.take_rows(rows),
                rows.iter().map(|&r| self.y[r]).collect(),
            );
            if !self.y_upper.is_empty() {
                d.y_upper = rows.iter().map(|&r| self.y_upper[r]).collect();
            }
            d
        };
        (take(train_idx), take(valid_idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_dense() -> DMatrix {
        // 3x3 with one missing
        DMatrix::dense(
            vec![1.0, 2.0, 3.0, 4.0, Float::NAN, 6.0, 7.0, 8.0, 9.0],
            3,
            3,
        )
    }

    fn sample_csr() -> DMatrix {
        // same logical content as sample_dense
        DMatrix::csr(
            vec![0, 3, 5, 8],
            vec![0, 1, 2, 0, 2, 0, 1, 2],
            vec![1.0, 2.0, 3.0, 4.0, 6.0, 7.0, 8.0, 9.0],
            3,
            3,
        )
    }

    #[test]
    fn get_dense_and_csr_agree() {
        let d = sample_dense();
        let s = sample_csr();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d.get(r, c), s.get(r, c), "({r},{c})");
            }
        }
        assert_eq!(d.get(1, 1), None);
    }

    #[test]
    fn nnz_and_density() {
        assert_eq!(sample_dense().nnz(), 8);
        assert_eq!(sample_csr().nnz(), 8);
        assert!((sample_dense().density() - 8.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn row_iter_skips_missing() {
        let d = sample_dense();
        let row: Vec<_> = d.iter_row(1).collect();
        assert_eq!(row, vec![(0, 4.0), (2, 6.0)]);
        let s = sample_csr();
        let row_s: Vec<_> = s.iter_row(1).collect();
        assert_eq!(row, row_s);
    }

    #[test]
    fn column_visit_agrees() {
        let d = sample_dense();
        let s = sample_csr();
        for c in 0..3 {
            let mut dv = Vec::new();
            let mut sv = Vec::new();
            d.for_each_in_column(c, |r, v| dv.push((r, v)));
            s.for_each_in_column(c, |r, v| sv.push((r, v)));
            assert_eq!(dv, sv);
        }
    }

    #[test]
    fn take_rows_dense() {
        let d = sample_dense();
        let sub = d.take_rows(&[2, 0]);
        assert_eq!(sub.n_rows(), 2);
        assert_eq!(sub.get(0, 0), Some(7.0));
        assert_eq!(sub.get(1, 2), Some(3.0));
    }

    #[test]
    fn take_rows_csr_preserves_missing() {
        let s = sample_csr();
        let sub = s.take_rows(&[1]);
        assert_eq!(sub.n_rows(), 1);
        assert_eq!(sub.get(0, 1), None);
        assert_eq!(sub.get(0, 2), Some(6.0));
    }

    #[test]
    fn csr_to_dense_roundtrip() {
        let s = sample_csr();
        let d = s.to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d.get(r, c), s.get(r, c));
            }
        }
    }

    #[test]
    fn dataset_split_partitions_rows() {
        let d = sample_dense();
        let ds = Dataset::new(d, vec![0.0, 1.0, 2.0]);
        let (train, valid) = ds.split(1.0 / 3.0, 7);
        assert_eq!(train.n_rows() + valid.n_rows(), 3);
        assert_eq!(valid.n_rows(), 1);
    }

    #[test]
    #[should_panic(expected = "labels/rows mismatch")]
    fn dataset_shape_check() {
        Dataset::new(sample_dense(), vec![0.0; 2]);
    }

    #[test]
    fn groups_validate() {
        let x = sample_dense();
        let ds = Dataset::with_groups(x, vec![0.0, 1.0, 0.0], vec![0, 2, 3]);
        assert_eq!(ds.groups.len(), 3);
    }

    #[test]
    fn float_bytes_dense() {
        assert_eq!(sample_dense().float_bytes(), 9 * 4);
    }

    #[test]
    fn bounds_default_to_labels() {
        let ds = Dataset::new(sample_dense(), vec![1.0, 2.0, 3.0]);
        assert_eq!(ds.bounds_upper(), &[1.0, 2.0, 3.0]);
        let b = Dataset::with_bounds(
            sample_dense(),
            vec![1.0, 2.0, 3.0],
            vec![1.0, Float::INFINITY, 5.0],
        );
        assert_eq!(b.bounds_upper()[2], 5.0);
        // split carries the bounds along with their rows
        let (train, valid) = b.split(1.0 / 3.0, 7);
        assert_eq!(train.y_upper.len(), train.y.len());
        assert_eq!(valid.y_upper.len(), valid.y.len());
    }
}
