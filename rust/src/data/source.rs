//! Out-of-core ingestion: pull-based [`BatchSource`] streams of bounded
//! row batches — the front door of the two-pass pipeline that sketches,
//! quantises and compresses training data **without ever materializing
//! the full float matrix** (paper §2.1–2.2; Ou, *Out-of-Core GPU Gradient
//! Boosting*, arXiv 2005.09148).
//!
//! # The two passes
//!
//! 1. **Sketch** ([`scan_source`]) — every batch is folded into the
//!    per-column [`StreamingSketch`](crate::quantile::StreamingSketch)
//!    (merge/prune per chunk), while O(`n_rows`) metadata accumulates:
//!    labels, qid-derived ranking groups, per-row present-value counts
//!    (the sparse ELLPACK strides of pass 2). The result is the frozen
//!    [`HistogramCuts`] plus an [`IngestMeta`].
//! 2. **Quantise + pack** — the source is [`reset`](BatchSource::reset)
//!    and re-streamed; each batch is quantised against the frozen cuts and
//!    bit-packed directly into the owning device shard's
//!    [`CompressedMatrixBuilder`](crate::compress::CompressedMatrixBuilder)
//!    pages (`MultiDeviceCoordinator::from_source`). With an
//!    external-memory budget (`max_resident_pages > 0`) the rows go to
//!    the shard's on-disk spill writer
//!    ([`PagedMatrixBuilder`](crate::compress::page::PagedMatrixBuilder))
//!    instead, so not even the packed words are a full-size allocation.
//!
//! # Peak-memory contract
//!
//! A `BatchSource` implementation must bound each batch by its configured
//! `batch_rows`, and the pipeline guarantees that the only full-size
//! (O(`n_rows`)) allocations are the **packed shard words themselves**
//! plus O(`n_rows`) scalar metadata (labels, per-row nnz). Peak transient
//! float-buffer bytes are O(`batch_rows × n_cols`), independent of the
//! dataset's row count — measured per ingest in
//! [`IngestMeta::peak_transient_bytes`] and tracked by
//! `benches/memory_footprint.rs` (`BENCH_memory.json`).
//!
//! # Determinism contract
//!
//! Re-streaming must reproduce the exact same rows in the same order
//! (pass 2 revisits what pass 1 sketched), and every value must be parsed
//! identically to the in-memory loaders — the file sources share the
//! per-line parsers of [`crate::data::loader`], which is what makes
//! `Learner::train_from_source` **bit-identical** to the in-memory
//! `Learner::train` for every batch size and thread count
//! (`rust/tests/streaming_ingest.rs`).

use std::fs::File;
use std::io::{BufRead, BufReader, Lines};
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::data::loader::{groups_from_qids, parse_libsvm_line, CsvLineParser};
use crate::data::synthetic::{self, DatasetSpec};
use crate::data::{DMatrix, Dataset};
use crate::exec::ExecContext;
use crate::quantile::{HistogramCuts, StreamingSketch};
use crate::Float;

/// Default batch size of the streaming readers (rows per batch). At the
/// paper's widest dense dataset (100 columns) this keeps the transient
/// float buffer around 26 MB.
pub const DEFAULT_BATCH_ROWS: usize = 65_536;

/// One bounded batch of rows pulled from a [`BatchSource`].
#[derive(Debug, Clone)]
pub struct RowBatch {
    /// Feature values of the batch's rows (dense or CSR, matching the
    /// source's layout). File sources with raw column indices
    /// ([`BatchSource::columns_are_raw`]) report them unshifted.
    pub x: DMatrix,
    /// Labels, one per row.
    pub y: Vec<Float>,
    /// Per-row query id (−1 = none). Empty when the source carries no
    /// ranking groups.
    pub qid: Vec<i64>,
    /// Per-row upper interval bounds for survival tasks (`y` holds the
    /// lowers). Empty when the source carries no interval labels — rows
    /// are then exact observations.
    pub y_upper: Vec<Float>,
}

impl RowBatch {
    pub fn n_rows(&self) -> usize {
        self.x.n_rows()
    }
}

/// A resettable, pull-based iterator of bounded row batches — the
/// abstraction every ingestion path (streaming CSV, streaming LibSVM, the
/// synthetic generators, in-memory matrices) plugs into. See the module
/// docs for the peak-memory and determinism contracts.
pub trait BatchSource {
    /// Rewind to the first row. Called between pass 1 and pass 2; the
    /// replayed stream must be identical to the first pass.
    fn reset(&mut self) -> Result<()>;

    /// Pull the next batch (at most the configured `batch_rows` rows), or
    /// `None` at end of stream.
    fn next_batch(&mut self) -> Result<Option<RowBatch>>;

    /// Whether column indices are raw file indices whose 0- vs 1-based
    /// convention is unresolved (LibSVM). When `true`, [`scan_source`]
    /// autodetects the base over the whole stream — exactly as the
    /// in-memory loader does — and reports it as
    /// [`IngestMeta::col_shift`].
    fn columns_are_raw(&self) -> bool {
        false
    }

    /// Minimum raw column index over the whole stream (`None` = no
    /// present values) — the evidence behind the 0-/1-based column-base
    /// autodetect. The streaming prediction paths call this up front
    /// (ingestion folds the same minimum into pass 1 for free), so file
    /// sources should override it with the cheapest scan they can —
    /// [`LibsvmSource`] reads index tokens only, skipping label/value
    /// parsing and the per-row sort/dedup. The default replays the
    /// stream through [`next_batch`](Self::next_batch), which is correct
    /// for any source, and leaves the source reset.
    fn min_raw_col(&mut self) -> Result<Option<u32>> {
        self.reset()?;
        let mut min: Option<u32> = None;
        while let Some(b) = self.next_batch()? {
            if let DMatrix::Csr { indices, .. } = &b.x {
                for &c in indices {
                    min = Some(min.map_or(c, |m| m.min(c)));
                }
            }
        }
        self.reset()?;
        Ok(min)
    }

    /// Human-readable name for logs.
    fn name(&self) -> &str {
        "source"
    }
}

/// Shared cursor for the in-memory adapters: walks a `(x, y, groups)`
/// triple in contiguous row windows, deriving per-row qids from group
/// membership so streamed group reconstruction is exact.
#[derive(Debug, Clone)]
struct MemCursor {
    batch_rows: usize,
    pos: usize,
    group_pos: usize,
}

impl MemCursor {
    fn new(batch_rows: usize) -> Self {
        MemCursor {
            batch_rows: batch_rows.max(1),
            pos: 0,
            group_pos: 0,
        }
    }

    fn reset(&mut self) {
        self.pos = 0;
        self.group_pos = 0;
    }

    fn next_batch(
        &mut self,
        x: &DMatrix,
        y: &[Float],
        groups: &[usize],
        y_upper: &[Float],
    ) -> Option<RowBatch> {
        let n = x.n_rows();
        if self.pos >= n {
            return None;
        }
        let hi = (self.pos + self.batch_rows).min(n);
        let rows: Vec<usize> = (self.pos..hi).collect();
        let batch_x = x.take_rows(&rows);
        // unlabeled adapters (coordinator-internal) stream zero labels
        let batch_y = if y.is_empty() {
            vec![0.0; hi - self.pos]
        } else {
            y[self.pos..hi].to_vec()
        };
        let qid = if groups.is_empty() {
            Vec::new()
        } else {
            let mut q = Vec::with_capacity(hi - self.pos);
            for r in self.pos..hi {
                while r >= groups[self.group_pos + 1] {
                    self.group_pos += 1;
                }
                q.push(self.group_pos as i64);
            }
            q
        };
        let batch_upper = if y_upper.is_empty() {
            Vec::new()
        } else {
            y_upper[self.pos..hi].to_vec()
        };
        self.pos = hi;
        Some(RowBatch {
            x: batch_x,
            y: batch_y,
            qid,
            y_upper: batch_upper,
        })
    }
}

/// In-memory adapter: streams a borrowed [`DMatrix`] (optionally with
/// labels and groups) in contiguous windows. This is how the legacy
/// `from_dmatrix` / `with_cuts` construction paths ride the streaming
/// pipeline — one code path for everything.
pub struct DMatrixSource<'a> {
    x: &'a DMatrix,
    y: Option<&'a [Float]>,
    groups: &'a [usize],
    y_upper: &'a [Float],
    cursor: MemCursor,
}

impl<'a> DMatrixSource<'a> {
    /// Unlabeled stream (coordinator-internal adapters; labels are zero).
    pub fn new(x: &'a DMatrix, batch_rows: usize) -> Self {
        DMatrixSource {
            x,
            y: None,
            groups: &[],
            y_upper: &[],
            cursor: MemCursor::new(batch_rows),
        }
    }

    /// Stream a full labelled dataset.
    pub fn from_dataset(ds: &'a Dataset, batch_rows: usize) -> Self {
        DMatrixSource {
            x: &ds.x,
            y: Some(&ds.y),
            groups: &ds.groups,
            y_upper: &ds.y_upper,
            cursor: MemCursor::new(batch_rows),
        }
    }
}

impl BatchSource for DMatrixSource<'_> {
    fn reset(&mut self) -> Result<()> {
        self.cursor.reset();
        Ok(())
    }

    fn next_batch(&mut self) -> Result<Option<RowBatch>> {
        let y: &[Float] = self.y.unwrap_or(&[]);
        debug_assert!(y.is_empty() || y.len() == self.x.n_rows());
        Ok(self.cursor.next_batch(self.x, y, self.groups, self.y_upper))
    }

    fn name(&self) -> &str {
        "in-memory"
    }
}

/// Adapter for the synthetic Table-1 generators: generates the dataset
/// once (the generators are in-memory by construction) and streams its
/// training split in bounded batches.
pub struct SyntheticSource {
    ds: Dataset,
    spec_name: &'static str,
    cursor: MemCursor,
}

impl SyntheticSource {
    /// Generate `(spec, seed)` and stream the training split.
    pub fn new(spec: &DatasetSpec, seed: u64, batch_rows: usize) -> Self {
        let g = synthetic::generate(spec, seed);
        SyntheticSource {
            ds: g.train,
            spec_name: spec.name,
            cursor: MemCursor::new(batch_rows),
        }
    }

    /// Stream an owned dataset (tests; pre-split data).
    pub fn from_dataset(ds: Dataset, batch_rows: usize) -> Self {
        SyntheticSource {
            ds,
            spec_name: "dataset",
            cursor: MemCursor::new(batch_rows),
        }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }
}

impl BatchSource for SyntheticSource {
    fn reset(&mut self) -> Result<()> {
        self.cursor.reset();
        Ok(())
    }

    fn next_batch(&mut self) -> Result<Option<RowBatch>> {
        Ok(self
            .cursor
            .next_batch(&self.ds.x, &self.ds.y, &self.ds.groups, &self.ds.y_upper))
    }

    fn name(&self) -> &str {
        self.spec_name
    }
}

/// Streaming CSV reader: resumable batches of dense rows, sharing the
/// per-line parser (and therefore every parse quirk) with
/// [`crate::data::load_csv`]. The field count learned from the first data
/// line persists across [`reset`](BatchSource::reset), so a file that
/// changes between passes fails loudly instead of silently skewing.
pub struct CsvSource {
    path: PathBuf,
    has_header: bool,
    batch_rows: usize,
    parser: CsvLineParser,
    lines: Option<Lines<BufReader<File>>>,
    lineno: usize,
}

impl CsvSource {
    pub fn open(
        path: impl AsRef<Path>,
        label_col: usize,
        has_header: bool,
        batch_rows: usize,
    ) -> Result<Self> {
        let mut s = CsvSource {
            path: path.as_ref().to_path_buf(),
            has_header,
            batch_rows: batch_rows.max(1),
            parser: CsvLineParser::new(label_col),
            lines: None,
            lineno: 0,
        };
        s.reset()?;
        Ok(s)
    }
}

impl BatchSource for CsvSource {
    fn reset(&mut self) -> Result<()> {
        let file = File::open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;
        self.lines = Some(BufReader::new(file).lines());
        self.lineno = 0;
        Ok(())
    }

    fn next_batch(&mut self) -> Result<Option<RowBatch>> {
        let lines = self.lines.as_mut().context("source not reset")?;
        let mut values: Vec<Float> = Vec::new();
        let mut labels: Vec<Float> = Vec::new();
        while labels.len() < self.batch_rows {
            let Some(line) = lines.next() else { break };
            let line = line.context("reading csv line")?;
            let lineno = self.lineno;
            self.lineno += 1;
            if lineno == 0 && self.has_header {
                continue;
            }
            if let Some(label) = self.parser.parse_line(&line, lineno, &mut values)? {
                labels.push(label);
            }
        }
        if labels.is_empty() {
            return Ok(None);
        }
        let n_cols = self.parser.n_cols().unwrap_or(0);
        Ok(Some(RowBatch {
            x: DMatrix::dense(values, labels.len(), n_cols),
            y: labels,
            qid: Vec::new(),
            y_upper: Vec::new(),
        }))
    }

    fn name(&self) -> &str {
        "csv"
    }
}

/// Streaming LibSVM reader: resumable batches of sparse (CSR) rows with
/// optional `qid:` tokens, sharing the per-line parser with
/// [`crate::data::load_libsvm`] (including the duplicate-index keep-last
/// rule). Column indices are emitted **raw**; the 0-/1-based autodetect
/// needs the whole stream and is performed by [`scan_source`]
/// ([`IngestMeta::col_shift`]).
pub struct LibsvmSource {
    path: PathBuf,
    batch_rows: usize,
    lines: Option<Lines<BufReader<File>>>,
    lineno: usize,
    /// Highest raw column index seen so far (persists across resets so
    /// pass-2 batches report a stable width).
    max_col: Option<u32>,
}

impl LibsvmSource {
    pub fn open(path: impl AsRef<Path>, batch_rows: usize) -> Result<Self> {
        let mut s = LibsvmSource {
            path: path.as_ref().to_path_buf(),
            batch_rows: batch_rows.max(1),
            lines: None,
            lineno: 0,
            max_col: None,
        };
        s.reset()?;
        Ok(s)
    }
}

impl BatchSource for LibsvmSource {
    fn reset(&mut self) -> Result<()> {
        let file = File::open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;
        self.lines = Some(BufReader::new(file).lines());
        self.lineno = 0;
        Ok(())
    }

    fn next_batch(&mut self) -> Result<Option<RowBatch>> {
        let lines = self.lines.as_mut().context("source not reset")?;
        let mut indptr = vec![0usize];
        let mut indices: Vec<u32> = Vec::new();
        let mut values: Vec<Float> = Vec::new();
        let mut labels: Vec<Float> = Vec::new();
        let mut qids: Vec<i64> = Vec::new();
        while labels.len() < self.batch_rows {
            let Some(line) = lines.next() else { break };
            let line = line.context("reading libsvm line")?;
            let lineno = self.lineno;
            self.lineno += 1;
            let Some(row) = parse_libsvm_line(&line, lineno)? else {
                continue;
            };
            labels.push(row.label);
            qids.push(row.qid);
            for (c, v) in row.pairs {
                self.max_col = Some(self.max_col.map_or(c, |m| m.max(c)));
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        if labels.is_empty() {
            return Ok(None);
        }
        let n_cols = self.max_col.map_or(0, |m| m as usize + 1);
        let n_rows = labels.len();
        Ok(Some(RowBatch {
            x: DMatrix::csr(indptr, indices, values, n_rows, n_cols),
            y: labels,
            qid: qids,
            y_upper: Vec::new(),
        }))
    }

    fn columns_are_raw(&self) -> bool {
        true
    }

    /// Index-token-only scan: strips comments and splits tokens exactly
    /// like [`parse_libsvm_line`] but never parses labels or float
    /// values and never sorts — malformed tokens are *skipped* here
    /// (the real parse raises the error when the stream is actually
    /// consumed). Roughly halves the cost of streaming prediction over
    /// LibSVM files versus replaying full batches for the column base.
    fn min_raw_col(&mut self) -> Result<Option<u32>> {
        let file = File::open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;
        let mut min: Option<u32> = None;
        for line in BufReader::new(file).lines() {
            let line = line.context("reading libsvm line")?;
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            for tok in line.split_ascii_whitespace().skip(1) {
                let Some(colon) = tok.find(':') else { continue };
                let (k, _) = tok.split_at(colon);
                if k == "qid" {
                    continue;
                }
                if let Ok(c) = k.parse::<u32>() {
                    min = Some(min.map_or(c, |m| m.min(c)));
                }
            }
        }
        Ok(min)
    }

    fn name(&self) -> &str {
        "libsvm"
    }
}

/// Pass-1 output: everything training needs to know about the stream
/// short of the feature values themselves. All fields are O(`n_rows`)
/// scalars or smaller — no float matrix.
#[derive(Debug, Clone, Default)]
pub struct IngestMeta {
    pub n_rows: usize,
    /// Feature count after column-base resolution.
    pub n_cols: usize,
    /// Subtracted from raw column indices in pass 2 (1 for 1-based LibSVM
    /// streams, 0 otherwise).
    pub col_shift: u32,
    /// Whether batches are dense (positional ELLPACK layout) or sparse.
    pub dense: bool,
    pub labels: Vec<Float>,
    /// Upper interval bounds aligned with `labels` (survival streams;
    /// empty when every row is an exact observation).
    pub labels_upper: Vec<Float>,
    /// Ranking group boundaries reconstructed from qids (empty = none).
    pub groups: Vec<usize>,
    /// Per-row present-value count (sparse streams only; empty for
    /// dense) — pass 2 derives each shard's ELLPACK stride from it.
    pub row_nnz: Vec<u32>,
    pub n_batches: usize,
    /// Largest single-batch float-buffer footprint seen in pass 1.
    pub peak_batch_float_bytes: usize,
    /// Peak transient (non-packed) bytes across both passes: batch floats
    /// plus the pass-2 symbol scratch. Filled by
    /// `MultiDeviceCoordinator::from_source`; the quantity the
    /// peak-memory contract bounds by O(`batch_rows × n_cols`).
    pub peak_transient_bytes: usize,
}

impl IngestMeta {
    /// Move the labels (and groups) out into a feature-less [`Dataset`] —
    /// the gradient/metric substrate for streamed training. The `x` is an
    /// empty CSR of the right shape: objectives and metrics only touch
    /// `y`/`groups`, and the coordinator already owns the quantised rows.
    pub fn take_label_dataset(&mut self) -> Dataset {
        let n = self.n_rows;
        let x = DMatrix::csr(vec![0usize; n + 1], Vec::new(), Vec::new(), n, self.n_cols);
        let y = std::mem::take(&mut self.labels);
        let groups = std::mem::take(&mut self.groups);
        let upper = std::mem::take(&mut self.labels_upper);
        let mut ds = if groups.is_empty() {
            Dataset::new(x, y)
        } else {
            Dataset::with_groups(x, y, groups)
        };
        ds.y_upper = upper;
        ds
    }
}

/// Fold one batch's row-aligned metadata into the accumulating
/// [`IngestMeta`] — shared between the sketching pass ([`scan_source`])
/// and the sketch-free resume pass ([`scan_source_meta`]) so both see
/// exactly the same labels, bounds, groups and sparsity.
fn fold_batch_meta(
    meta: &mut IngestMeta,
    qids: &mut Vec<i64>,
    dense: &mut Option<bool>,
    min_col: &mut u32,
    raw_cols: bool,
    batch: &RowBatch,
) -> Result<()> {
    let b_rows = batch.n_rows();
    ensure!(b_rows > 0, "source yielded an empty batch");
    let batch_dense = matches!(batch.x, DMatrix::Dense { .. });
    match *dense {
        None => *dense = Some(batch_dense),
        Some(d) => ensure!(
            d == batch_dense,
            "source switched between dense and sparse batches"
        ),
    }
    ensure!(batch.y.len() == b_rows, "batch labels/rows mismatch");
    // Interval bounds: once any batch carries them, every row needs one;
    // bound-less batches contribute exact observations (upper == label).
    if !batch.y_upper.is_empty() || !meta.labels_upper.is_empty() {
        if meta.labels_upper.is_empty() {
            meta.labels_upper = meta.labels.clone();
        }
        if batch.y_upper.is_empty() {
            meta.labels_upper.extend_from_slice(&batch.y);
        } else {
            ensure!(
                batch.y_upper.len() == b_rows,
                "batch interval bounds/rows mismatch"
            );
            meta.labels_upper.extend_from_slice(&batch.y_upper);
        }
    }
    meta.labels.extend_from_slice(&batch.y);
    if batch.qid.is_empty() {
        qids.resize(qids.len() + b_rows, -1);
    } else {
        ensure!(batch.qid.len() == b_rows, "batch qids/rows mismatch");
        qids.extend_from_slice(&batch.qid);
    }
    if let DMatrix::Csr {
        indptr, indices, ..
    } = &batch.x
    {
        for r in 0..b_rows {
            meta.row_nnz.push((indptr[r + 1] - indptr[r]) as u32);
        }
        if raw_cols {
            for &c in indices {
                *min_col = (*min_col).min(c);
            }
        }
    }
    meta.peak_batch_float_bytes = meta.peak_batch_float_bytes.max(batch.x.float_bytes());
    meta.n_batches += 1;
    meta.n_rows += b_rows;
    Ok(())
}

/// **Pass 1**: stream the whole source once, folding every batch into the
/// per-column quantile sketch and accumulating [`IngestMeta`]. Returns the
/// frozen [`HistogramCuts`] the second pass quantises against.
///
/// The sketch fold is chunk-parallel over columns on `exec`; cuts are
/// bit-identical for every batch size and thread count (see
/// [`StreamingSketch`]).
pub fn scan_source(
    src: &mut dyn BatchSource,
    max_bins: usize,
    exec: &ExecContext,
) -> Result<(HistogramCuts, IngestMeta)> {
    scan_source_with_categories(src, max_bins, &[], exec)
}

/// [`scan_source`] with per-feature categorical flags: flagged columns
/// additionally accumulate their **exact distinct value set** during the
/// sketch pass, and the finished cuts replace those features' quantile
/// cuts with one-bin-per-category cuts
/// ([`HistogramCuts::apply_categories`]). Category codes must be
/// non-negative integers below 64 (the split-bitset width); anything
/// else fails loudly here rather than mis-binning silently.
pub fn scan_source_with_categories(
    src: &mut dyn BatchSource,
    max_bins: usize,
    categorical: &[usize],
    exec: &ExecContext,
) -> Result<(HistogramCuts, IngestMeta)> {
    use std::collections::{BTreeMap, BTreeSet};

    let raw_cols = src.columns_are_raw();
    let mut sketch = StreamingSketch::new(max_bins);
    let mut meta = IngestMeta::default();
    let mut qids: Vec<i64> = Vec::new();
    let mut dense: Option<bool> = None;
    let mut min_col: u32 = u32::MAX;
    // Raw column indices whose values we must collect. The column base
    // of raw (LibSVM) streams is unresolved until the end of the pass,
    // so watch both candidate raw columns (`f` and `f+1`) and pick the
    // right one once the shift is known.
    let wanted: BTreeSet<usize> = categorical
        .iter()
        .flat_map(|&f| [f, f + 1])
        .collect();
    let mut seen_values: BTreeMap<usize, BTreeSet<u32>> = BTreeMap::new();

    while let Some(batch) = src.next_batch()? {
        fold_batch_meta(&mut meta, &mut qids, &mut dense, &mut min_col, raw_cols, &batch)?;
        if !wanted.is_empty() {
            for r in 0..batch.n_rows() {
                for (c, v) in batch.x.iter_row(r) {
                    if wanted.contains(&c) {
                        seen_values.entry(c).or_default().insert(v.to_bits());
                    }
                }
            }
        }
        sketch.fold(&batch.x, exec);
    }

    meta.dense = dense.unwrap_or(true);
    // 1-based index files never use column 0 (same rule as the loader).
    meta.col_shift = u32::from(raw_cols && sketch.n_cols() > 0 && min_col >= 1);
    let summaries = sketch.finish();
    let shift = meta.col_shift as usize;
    let feature_summaries = &summaries[shift.min(summaries.len())..];
    meta.n_cols = feature_summaries.len();
    meta.groups = groups_from_qids(&qids)?;
    let mut cuts = HistogramCuts::from_summaries(feature_summaries, max_bins);

    if !categorical.is_empty() {
        let mut cat_values: BTreeMap<usize, Vec<Float>> = BTreeMap::new();
        for &f in categorical {
            ensure!(
                f < meta.n_cols,
                "categorical feature f{f} out of range (stream has {} features)",
                meta.n_cols
            );
            let set = seen_values.get(&(f + shift)).cloned().unwrap_or_default();
            ensure!(
                !set.is_empty(),
                "categorical feature f{f} has no present values in the stream"
            );
            let mut vals: Vec<Float> = set.iter().map(|&b| Float::from_bits(b)).collect();
            for &v in &vals {
                ensure!(
                    v.is_finite() && v >= 0.0 && v < 64.0 && v.fract() == 0.0,
                    "categorical feature f{f} has value {v} — category codes \
                     must be integers in [0, 64)"
                );
            }
            vals.sort_by(|a, b| a.partial_cmp(b).expect("finite values compare"));
            cat_values.insert(f, vals);
        }
        cuts.apply_categories(&cat_values);
    }
    Ok((cuts, meta))
}

/// Sketch-free pass 1 for **training continuation**: accumulates the same
/// [`IngestMeta`] as [`scan_source`] (labels, interval bounds, groups,
/// per-row nnz, column base) without building a quantile sketch — resume
/// quantises against the cuts frozen in the serialized model, so
/// sketching the new stream would be wasted work.
pub fn scan_source_meta(src: &mut dyn BatchSource) -> Result<IngestMeta> {
    let raw_cols = src.columns_are_raw();
    let mut meta = IngestMeta::default();
    let mut qids: Vec<i64> = Vec::new();
    let mut dense: Option<bool> = None;
    let mut min_col: u32 = u32::MAX;
    let mut max_cols: usize = 0;

    while let Some(batch) = src.next_batch()? {
        fold_batch_meta(&mut meta, &mut qids, &mut dense, &mut min_col, raw_cols, &batch)?;
        max_cols = max_cols.max(batch.x.n_cols());
    }

    meta.dense = dense.unwrap_or(true);
    meta.col_shift = u32::from(raw_cols && min_col != u32::MAX && min_col >= 1);
    meta.n_cols = max_cols.saturating_sub(meta.col_shift as usize);
    meta.groups = groups_from_qids(&qids)?;
    Ok(meta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::{save_csv, save_libsvm};
    use crate::data::synthetic::generate;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("xgb_tpu_source_{name}"))
    }

    fn collect(src: &mut dyn BatchSource) -> (Vec<Float>, usize, usize) {
        let mut y = Vec::new();
        let mut rows = 0;
        let mut batches = 0;
        while let Some(b) = src.next_batch().unwrap() {
            rows += b.n_rows();
            batches += 1;
            y.extend(b.y);
        }
        (y, rows, batches)
    }

    #[test]
    fn dmatrix_source_streams_all_rows_in_order() {
        let g = generate(&DatasetSpec::higgs_like(250), 3);
        let mut src = DMatrixSource::from_dataset(&g.train, 32);
        let (y, rows, batches) = collect(&mut src);
        assert_eq!(rows, g.train.n_rows());
        assert_eq!(batches, g.train.n_rows().div_ceil(32));
        assert_eq!(y, g.train.y);
        // reset replays identically
        src.reset().unwrap();
        let (y2, rows2, _) = collect(&mut src);
        assert_eq!(rows2, rows);
        assert_eq!(y2, y);
    }

    #[test]
    fn mem_cursor_derives_qids_from_groups() {
        let g = generate(&DatasetSpec::ranking_like(200), 5);
        let mut src = DMatrixSource::from_dataset(&g.train, 17);
        let mut qids = Vec::new();
        while let Some(b) = src.next_batch().unwrap() {
            assert_eq!(b.qid.len(), b.n_rows());
            qids.extend(b.qid);
        }
        let rebuilt = groups_from_qids(&qids).unwrap();
        assert_eq!(rebuilt, g.train.groups);
    }

    #[test]
    fn csv_source_matches_in_memory_loader() {
        let g = generate(&DatasetSpec::airline_like(300), 7);
        let path = tmp("csv_match.csv");
        save_csv(&g.train, &path).unwrap();
        let mem = crate::data::load_csv(&path, 0, false).unwrap();
        let mut src = CsvSource::open(&path, 0, false, 41).unwrap();
        let mut row = 0usize;
        while let Some(b) = src.next_batch().unwrap() {
            for i in 0..b.n_rows() {
                assert_eq!(b.y[i], mem.y[row]);
                let a: Vec<_> = b.x.iter_row(i).collect();
                let e: Vec<_> = mem.x.iter_row(row).collect();
                assert_eq!(a, e, "row {row}");
                row += 1;
            }
        }
        assert_eq!(row, mem.n_rows());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn libsvm_source_scan_matches_in_memory_loader() {
        // sparse + qid + 1-based indices (save_libsvm writes 1-based)
        let g = generate(&DatasetSpec::ranking_like(240), 11);
        let path = tmp("libsvm_match.libsvm");
        save_libsvm(&g.train, &path).unwrap();
        let mem = crate::data::load_libsvm(&path).unwrap();

        let exec = ExecContext::serial();
        let mut src = LibsvmSource::open(&path, 23).unwrap();
        let (cuts, meta) = scan_source(&mut src, 16, &exec).unwrap();
        assert_eq!(meta.n_rows, mem.n_rows());
        assert_eq!(meta.n_cols, mem.n_cols());
        assert_eq!(meta.col_shift, 1, "save_libsvm writes 1-based indices");
        assert_eq!(meta.labels, mem.y);
        assert_eq!(meta.groups, mem.groups);
        assert!(!meta.dense);
        assert_eq!(meta.row_nnz.len(), mem.n_rows());

        // cuts equal the in-memory streaming fold over the loaded matrix
        let mut mem_src = DMatrixSource::new(&mem.x, 1000);
        let (mem_cuts, _) = scan_source(&mut mem_src, 16, &exec).unwrap();
        assert_eq!(cuts, mem_cuts);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn libsvm_min_raw_col_matches_full_parse() {
        let g = generate(&DatasetSpec::ranking_like(120), 21);
        let path = tmp("mincol.libsvm");
        save_libsvm(&g.train, &path).unwrap();
        // a comment line and a blank line must not count as indices
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "# 0:99 comment indices must be ignored").unwrap();
            writeln!(f).unwrap();
        }
        let mut src = LibsvmSource::open(&path, 16).unwrap();
        let fast = src.min_raw_col().unwrap();
        // reference: the trait's default full-replay detection
        src.reset().unwrap();
        let mut slow: Option<u32> = None;
        while let Some(b) = src.next_batch().unwrap() {
            if let DMatrix::Csr { indices, .. } = &b.x {
                for &c in indices {
                    slow = Some(slow.map_or(c, |m| m.min(c)));
                }
            }
        }
        assert_eq!(fast, slow);
        assert_eq!(fast, Some(1), "save_libsvm writes 1-based indices");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scan_cuts_invariant_to_batch_size() {
        let g = generate(&DatasetSpec::higgs_like(400), 13);
        let exec = ExecContext::serial();
        let reference = {
            let mut src = DMatrixSource::from_dataset(&g.train, g.train.n_rows());
            scan_source(&mut src, 16, &exec).unwrap().0
        };
        for batch in [7usize, 64, 301] {
            let mut src = DMatrixSource::from_dataset(&g.train, batch);
            let (cuts, meta) = scan_source(&mut src, 16, &exec).unwrap();
            assert_eq!(cuts, reference, "batch={batch}");
            assert_eq!(meta.n_batches, g.train.n_rows().div_ceil(batch));
            // transient floats bounded by the batch, not the dataset
            assert!(
                meta.peak_batch_float_bytes <= batch * g.train.n_cols() * 4,
                "batch={batch}: {} bytes",
                meta.peak_batch_float_bytes
            );
        }
    }

    #[test]
    fn interval_bounds_stream_through_scan() {
        let g = generate(&DatasetSpec::higgs_like(120), 23);
        let n = g.train.n_rows();
        let upper: Vec<Float> = g.train.y.iter().map(|&v| v + 1.0).collect();
        let ds = Dataset::with_bounds(g.train.x.clone(), g.train.y.clone(), upper.clone());
        let exec = ExecContext::serial();
        // bounds survive batching at any batch size
        for batch in [13usize, n] {
            let mut src = DMatrixSource::from_dataset(&ds, batch);
            let (_, mut meta) = scan_source(&mut src, 8, &exec).unwrap();
            assert_eq!(meta.labels_upper, upper, "batch={batch}");
            let out = meta.take_label_dataset();
            assert_eq!(out.bounds_upper(), &upper[..]);
            assert_eq!(out.n_rows(), n);
        }
        // bound-less streams keep labels_upper empty
        let mut src = DMatrixSource::from_dataset(&g.train, 13);
        let (_, meta) = scan_source(&mut src, 8, &exec).unwrap();
        assert!(meta.labels_upper.is_empty());
    }

    #[test]
    fn categorical_scan_builds_exact_category_bins() {
        // f0 numeric, f1 categorical with codes {0, 3, 5}
        let n = 90usize;
        let mut v = Vec::new();
        let mut rng = crate::util::Pcg64::new(7);
        for r in 0..n {
            v.push(rng.next_f32() * 4.0);
            v.push([0.0, 3.0, 5.0][r % 3] as Float);
        }
        let ds = Dataset::new(DMatrix::dense(v, n, 2), vec![1.0; n]);
        let exec = ExecContext::serial();
        for batch in [11usize, n] {
            let mut src = DMatrixSource::from_dataset(&ds, batch);
            let (cuts, meta) =
                scan_source_with_categories(&mut src, 16, &[1], &exec).unwrap();
            assert_eq!(meta.n_cols, 2);
            assert!(!cuts.is_categorical(0));
            assert!(cuts.is_categorical(1));
            assert_eq!(cuts.feature_bins(1), 3, "batch={batch}");
            for (i, &c) in [0.0 as Float, 3.0, 5.0].iter().enumerate() {
                let b = cuts.bin_index(1, c);
                assert_eq!((b - cuts.ptrs[1]) as usize, i, "category {c}");
                assert_eq!(cuts.category_of_local_bin(1, i), c);
            }
        }
        // non-integer and out-of-range codes fail loudly
        let bad = Dataset::new(DMatrix::dense(vec![0.5, 1.0, 2.0, 3.0], 4, 1), vec![0.0; 4]);
        let mut src = DMatrixSource::from_dataset(&bad, 4);
        let err = scan_source_with_categories(&mut src, 8, &[0], &exec).unwrap_err();
        assert!(err.to_string().contains("category codes"), "{err}");
        let big = Dataset::new(DMatrix::dense(vec![1.0, 64.0, 2.0, 3.0], 4, 1), vec![0.0; 4]);
        let mut src = DMatrixSource::from_dataset(&big, 4);
        assert!(scan_source_with_categories(&mut src, 8, &[0], &exec).is_err());
        // out-of-range feature index
        let mut src = DMatrixSource::from_dataset(&ds, 16);
        assert!(scan_source_with_categories(&mut src, 8, &[2], &exec).is_err());
    }

    #[test]
    fn scan_source_meta_matches_sketching_scan() {
        let g = generate(&DatasetSpec::ranking_like(180), 29);
        let path = tmp("meta_scan.libsvm");
        save_libsvm(&g.train, &path).unwrap();
        let exec = ExecContext::serial();
        let mut src = LibsvmSource::open(&path, 19).unwrap();
        let (_, full) = scan_source(&mut src, 16, &exec).unwrap();
        let mut src2 = LibsvmSource::open(&path, 19).unwrap();
        let light = scan_source_meta(&mut src2).unwrap();
        assert_eq!(light.n_rows, full.n_rows);
        assert_eq!(light.n_cols, full.n_cols);
        assert_eq!(light.col_shift, full.col_shift);
        assert_eq!(light.labels, full.labels);
        assert_eq!(light.groups, full.groups);
        assert_eq!(light.row_nnz, full.row_nnz);
        assert_eq!(light.dense, full.dense);
        assert_eq!(light.n_batches, full.n_batches);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn label_dataset_carries_groups() {
        let g = generate(&DatasetSpec::ranking_like(150), 19);
        let exec = ExecContext::serial();
        let mut src = DMatrixSource::from_dataset(&g.train, 16);
        let (_, mut meta) = scan_source(&mut src, 8, &exec).unwrap();
        let ds = meta.take_label_dataset();
        assert_eq!(ds.n_rows(), g.train.n_rows());
        assert_eq!(ds.y, g.train.y);
        assert_eq!(ds.groups, g.train.groups);
        assert_eq!(ds.x.nnz(), 0, "label dataset holds no feature values");
    }
}
