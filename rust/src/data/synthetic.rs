//! Synthetic stand-ins for the paper's six public datasets (Table 1).
//!
//! The evaluation machine for this reproduction has no network access, so
//! YearPredictionMSD, sklearn-Synthetic, Higgs, Cover Type, Bosch and
//! Airline are replaced by deterministic generators matched to each
//! dataset's *schema* (column count, task type, sparsity, class balance)
//! and given a learnable-but-noisy signal so accuracy numbers are
//! non-trivial (see `DESIGN.md` §2). Row counts default to 1/100 of the
//! paper's scale and are adjustable via [`DatasetSpec`]`.rows` or the bench
//! harness `--scale` flag.

use crate::data::{DMatrix, Dataset};
use crate::util::Pcg64;
use crate::Float;

/// Learning task of a dataset, mirroring Table 1's "Task" column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Regression,
    Binary,
    /// Multiclass with `n` classes.
    Multiclass(usize),
    /// Learning-to-rank with the given mean group size.
    Ranking(usize),
}

impl Task {
    /// Default objective name (parses into
    /// [`crate::gbm::ObjectiveKind`] losslessly).
    pub fn objective(&self) -> &'static str {
        match self {
            Task::Regression => "reg:squarederror",
            Task::Binary => "binary:logistic",
            Task::Multiclass(_) => "multi:softmax",
            Task::Ranking(_) => "rank:pairwise",
        }
    }

    /// Default evaluation metric, matching what Table 2 reports.
    pub fn metric(&self) -> &'static str {
        match self {
            Task::Regression => "rmse",
            Task::Binary => "accuracy",
            Task::Multiclass(_) => "accuracy",
            Task::Ranking(_) => "ndcg",
        }
    }

    pub fn num_class(&self) -> usize {
        match self {
            Task::Multiclass(k) => *k,
            _ => 1,
        }
    }
}

/// Which of the paper's datasets to emulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// YearPredictionMSD: dense audio features, regression.
    YearPrediction,
    /// scikit-learn `make_regression`-style linear problem.
    Synthetic,
    /// HIGGS: physics detector features, binary.
    Higgs,
    /// Forest Cover Type: mixed continuous + one-hot, 7 classes.
    CovType,
    /// Bosch production line: very wide, very sparse, imbalanced binary.
    Bosch,
    /// Airline on-time: few mixed-cardinality columns, huge row count.
    Airline,
    /// Web search ranking (for the `rank:pairwise` objective; not in
    /// Table 1 but exercised by the paper's "ranking" claim in §1).
    Ranking,
}

/// Specification of a synthetic dataset instance.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub family: Family,
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub task: Task,
    /// Fraction of rows held out for validation.
    pub valid_frac: f64,
}

impl DatasetSpec {
    pub fn year_prediction_like(rows: usize) -> Self {
        DatasetSpec {
            family: Family::YearPrediction,
            name: "YearPredictionMSD",
            rows,
            cols: 90,
            task: Task::Regression,
            valid_frac: 0.2,
        }
    }

    pub fn synthetic_like(rows: usize) -> Self {
        DatasetSpec {
            family: Family::Synthetic,
            name: "Synthetic",
            rows,
            cols: 100,
            task: Task::Regression,
            valid_frac: 0.2,
        }
    }

    pub fn higgs_like(rows: usize) -> Self {
        DatasetSpec {
            family: Family::Higgs,
            name: "Higgs",
            rows,
            cols: 28,
            task: Task::Binary,
            valid_frac: 0.2,
        }
    }

    pub fn covtype_like(rows: usize) -> Self {
        DatasetSpec {
            family: Family::CovType,
            name: "Cover Type",
            rows,
            cols: 54,
            task: Task::Multiclass(7),
            valid_frac: 0.2,
        }
    }

    pub fn bosch_like(rows: usize) -> Self {
        DatasetSpec {
            family: Family::Bosch,
            name: "Bosch",
            rows,
            cols: 968,
            task: Task::Binary,
            valid_frac: 0.2,
        }
    }

    pub fn airline_like(rows: usize) -> Self {
        DatasetSpec {
            family: Family::Airline,
            name: "Airline",
            rows,
            cols: 13,
            task: Task::Binary,
            valid_frac: 0.2,
        }
    }

    pub fn ranking_like(rows: usize) -> Self {
        DatasetSpec {
            family: Family::Ranking,
            name: "WebRank",
            rows,
            cols: 40,
            task: Task::Ranking(20),
            valid_frac: 0.2,
        }
    }

    /// The paper's Table 1 datasets at `scale` (1.0 = paper scale; the
    /// bench harness defaults to 0.01).
    pub fn table1(scale: f64) -> Vec<DatasetSpec> {
        let s = |n: usize| ((n as f64 * scale).round() as usize).max(1000);
        vec![
            DatasetSpec::year_prediction_like(s(515_000)),
            DatasetSpec::synthetic_like(s(10_000_000)),
            DatasetSpec::higgs_like(s(11_000_000)),
            DatasetSpec::covtype_like(s(581_000)),
            DatasetSpec::bosch_like(s(1_000_000)),
            DatasetSpec::airline_like(s(115_000_000)),
        ]
    }

    /// Look up a spec by (case-insensitive) name with an explicit row count.
    pub fn by_name(name: &str, rows: usize) -> Option<DatasetSpec> {
        let n = name.to_ascii_lowercase();
        Some(match n.as_str() {
            "yearprediction" | "yearpredictionmsd" | "year" | "msd" => {
                DatasetSpec::year_prediction_like(rows)
            }
            "synthetic" => DatasetSpec::synthetic_like(rows),
            "higgs" => DatasetSpec::higgs_like(rows),
            "covtype" | "cover_type" | "covertype" => DatasetSpec::covtype_like(rows),
            "bosch" => DatasetSpec::bosch_like(rows),
            "airline" => DatasetSpec::airline_like(rows),
            "ranking" | "webrank" => DatasetSpec::ranking_like(rows),
            _ => return None,
        })
    }
}

/// A generated dataset with train/validation split.
#[derive(Debug, Clone)]
pub struct Generated {
    pub spec: DatasetSpec,
    pub train: Dataset,
    pub valid: Dataset,
}

/// Generate a dataset deterministically from `(spec, seed)`.
pub fn generate(spec: &DatasetSpec, seed: u64) -> Generated {
    let full = match spec.family {
        Family::YearPrediction => gen_year_prediction(spec, seed),
        Family::Synthetic => gen_synthetic_regression(spec, seed),
        Family::Higgs => gen_higgs(spec, seed),
        Family::CovType => gen_covtype(spec, seed),
        Family::Bosch => gen_bosch(spec, seed),
        Family::Airline => gen_airline(spec, seed),
        Family::Ranking => return gen_ranking(spec, seed),
    };
    let (train, valid) = full.split(spec.valid_frac, seed ^ 0x5eed);
    Generated {
        spec: spec.clone(),
        train,
        valid,
    }
}

/// YearPredictionMSD-like: 90 correlated "timbre" features; target is a
/// smooth nonlinear function mapped into the 1922–2011 "year" range plus
/// noise, so RMSE lands in the high-single-digit band like the paper's.
fn gen_year_prediction(spec: &DatasetSpec, seed: u64) -> Dataset {
    let root = Pcg64::new(seed);
    let mut rng = root.split(1);
    let n = spec.rows;
    let d = spec.cols;
    // latent factors induce feature correlation like real audio covariances
    let k = 12;
    let loadings: Vec<f64> = (0..d * k).map(|_| rng.next_gaussian() * 0.6).collect();
    let mut values = vec![0.0 as Float; n * d];
    let mut y = vec![0.0 as Float; n];
    let mut latent = vec![0.0f64; k];
    for row in 0..n {
        for z in latent.iter_mut() {
            *z = rng.next_gaussian();
        }
        let mut signal = 0.0f64;
        for c in 0..d {
            let mut v = rng.next_gaussian() * 0.5;
            for (j, z) in latent.iter().enumerate() {
                v += loadings[c * k + j] * z;
            }
            values[row * d + c] = v as Float;
        }
        // target: smooth function of the first few latents
        signal += 6.0 * (latent[0]).tanh();
        signal += 3.5 * (latent[1] * latent[2]).tanh();
        signal += 2.0 * latent[3];
        signal += 1.5 * (latent[4].abs() - 0.8);
        let noise = rng.next_gaussian() * 7.0;
        y[row] = (1998.0 + signal * 2.0 + noise).clamp(1922.0, 2011.0) as Float;
    }
    Dataset::new(DMatrix::dense(values, n, d), y)
}

/// sklearn `make_regression`-like: linear model on a sparse-informative
/// subset of 100 gaussian features plus gaussian noise.
fn gen_synthetic_regression(spec: &DatasetSpec, seed: u64) -> Dataset {
    let root = Pcg64::new(seed);
    let mut rng = root.split(2);
    let n = spec.rows;
    let d = spec.cols;
    let informative = 10.min(d);
    let coefs: Vec<f64> = (0..informative)
        .map(|_| rng.next_gaussian() * 50.0)
        .collect();
    let mut values = vec![0.0 as Float; n * d];
    let mut y = vec![0.0 as Float; n];
    for row in 0..n {
        let mut t = 0.0f64;
        for c in 0..d {
            let v = rng.next_gaussian();
            values[row * d + c] = v as Float;
            if c < informative {
                t += coefs[c] * v;
            }
        }
        // scale into the paper's RMSE~13.5 band: noise sigma ~ 13
        y[row] = (t * 0.1 + rng.next_gaussian() * 13.0) as Float;
    }
    Dataset::new(DMatrix::dense(values, n, d), y)
}

/// HIGGS-like: 21 "low-level" + 7 "high-level" features; the class signal
/// lives in nonlinear combinations (as in Baldi et al.), tuned so boosted
/// trees reach ~74–76% accuracy like the paper's Table 2.
fn gen_higgs(spec: &DatasetSpec, seed: u64) -> Dataset {
    let root = Pcg64::new(seed);
    let mut rng = root.split(3);
    let n = spec.rows;
    let d = spec.cols; // 28
    let mut values = vec![0.0 as Float; n * d];
    let mut y = vec![0.0 as Float; n];
    for row in 0..n {
        let label = rng.next_f64() < 0.53; // signal fraction like HIGGS
        let shift = if label { 0.5 } else { 0.0 };
        let mut low = [0.0f64; 21];
        for (c, l) in low.iter_mut().enumerate() {
            // signal shifts a few kinematic features; heavy tails via exp
            let base = rng.next_gaussian();
            let v = if c % 4 == 0 {
                (base + shift * 0.6).exp() * 0.5
            } else if c % 4 == 1 {
                base + shift * 0.45
            } else {
                base
            };
            *l = v;
            values[row * d + c] = v as Float;
        }
        // high-level: invariant-mass-like combinations, where most of the
        // separation lives
        for c in 21..d {
            let i = (c - 21) * 3 % 21;
            let j = ((c - 21) * 5 + 7) % 21;
            let m = (low[i] * low[i] + low[j] * low[j]).sqrt()
                + shift * 0.7
                + rng.next_gaussian() * 0.4;
            values[row * d + c] = m as Float;
        }
        y[row] = if label { 1.0 } else { 0.0 };
    }
    Dataset::new(DMatrix::dense(values, n, d), y)
}

/// Forest-CoverType-like: 10 continuous terrain features + 4 one-hot
/// wilderness-area + 40 one-hot soil-type columns; 7 classes with skewed
/// priors, decision structure aligned to terrain thresholds.
fn gen_covtype(spec: &DatasetSpec, seed: u64) -> Dataset {
    let root = Pcg64::new(seed);
    let mut rng = root.split(4);
    let n = spec.rows;
    let d = spec.cols; // 54
    let mut values = vec![0.0 as Float; n * d];
    let mut y = vec![0.0 as Float; n];
    for row in 0..n {
        let elevation = 1800.0 + rng.next_f64() * 1800.0;
        let aspect = rng.next_f64() * 360.0;
        let slope = rng.next_f64() * 50.0;
        let hydro_d = rng.next_f64() * 1200.0;
        let road_d = rng.next_f64() * 6000.0;
        let hillshade = 120.0 + rng.next_f64() * 130.0;
        let cont = [
            elevation,
            aspect,
            slope,
            hydro_d,
            rng.next_f64() * 500.0 - 100.0, // vertical hydro
            road_d,
            hillshade,
            hillshade + rng.next_gaussian() * 15.0,
            hillshade + rng.next_gaussian() * 25.0,
            rng.next_f64() * 7000.0, // fire points
        ];
        for (c, v) in cont.iter().enumerate() {
            values[row * d + c] = *v as Float;
        }
        let wilderness = rng.gen_range(4);
        values[row * d + 10 + wilderness] = 1.0;
        let soil = rng.gen_range(40);
        values[row * d + 14 + soil] = 1.0;
        // class from elevation bands + modifiers, plus noise: mirrors the
        // real dataset where elevation dominates
        let band = ((elevation - 1800.0) / 1800.0 * 6.99) as usize;
        let mut class = band.min(6) as i64;
        if slope > 35.0 {
            class = (class + 1).min(6);
        }
        if wilderness == 3 && class > 0 {
            class -= 1;
        }
        if soil < 8 && class > 1 {
            class -= 1;
        }
        if rng.next_f64() < 0.12 {
            class = rng.gen_range(7) as i64; // label noise
        }
        y[row] = class as Float;
    }
    Dataset::new(DMatrix::dense(values, n, d), y)
}

/// Bosch-like: 968 sensor columns, ~20% present (CSR), heavily imbalanced
/// binary labels (~0.6% positives in the real data; we use 1.5% so tiny
/// scaled-down runs still see positives), weak signal spread over many
/// stations.
fn gen_bosch(spec: &DatasetSpec, seed: u64) -> Dataset {
    let root = Pcg64::new(seed);
    let mut rng = root.split(5);
    let n = spec.rows;
    let d = spec.cols; // 968
    let p_present = 0.19;
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<Float> = Vec::new();
    let mut y = vec![0.0 as Float; n];
    // station-level fault weights
    let weights: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.35).collect();
    for row in 0..n {
        let mut score = -4.3f64; // intercept -> rare positives
        for c in 0..d {
            if rng.next_f64() < p_present {
                let v = rng.next_gaussian();
                indices.push(c as u32);
                values.push(v as Float);
                score += weights[c] * v * 0.35;
            }
        }
        indptr.push(indices.len());
        let p = 1.0 / (1.0 + (-score).exp());
        y[row] = if rng.next_f64() < p { 1.0 } else { 0.0 };
    }
    Dataset::new(DMatrix::csr(indptr, indices, values, n, d), y)
}

/// Airline-like: 13 mixed columns (month, day-of-week, carrier id, origin/
/// dest ids, departure time, distance, ...), binary "delayed" label with
/// structure on carrier × time-of-day × distance. Integer-coded
/// categoricals, exactly how the paper's benchmark ingests the real file.
fn gen_airline(spec: &DatasetSpec, seed: u64) -> Dataset {
    let root = Pcg64::new(seed);
    let mut rng = root.split(6);
    let n = spec.rows;
    let d = spec.cols; // 13
    let mut values = vec![0.0 as Float; n * d];
    let mut y = vec![0.0 as Float; n];
    let n_carriers = 22usize;
    let n_airports = 300usize;
    let carrier_bias: Vec<f64> = (0..n_carriers).map(|_| rng.next_gaussian() * 0.5).collect();
    let airport_bias: Vec<f64> = (0..n_airports).map(|_| rng.next_gaussian() * 0.35).collect();
    for row in 0..n {
        let month = rng.gen_range(12) as f64 + 1.0;
        let day_of_month = rng.gen_range(28) as f64 + 1.0;
        let day_of_week = rng.gen_range(7) as f64 + 1.0;
        let dep_time = rng.next_f64() * 24.0; // hours
        let carrier = rng.gen_range(n_carriers);
        let origin = rng.gen_range(n_airports);
        let dest = rng.gen_range(n_airports);
        let distance = 100.0 + rng.next_f64().powi(2) * 2800.0;
        let air_time = distance / 7.5 + rng.next_gaussian() * 8.0;
        let taxi = 5.0 + rng.next_f64() * 25.0;
        let cols = [
            month,
            day_of_month,
            day_of_week,
            dep_time,
            carrier as f64,
            origin as f64,
            dest as f64,
            distance,
            air_time,
            taxi,
            (month * 30.0 + day_of_month), // day-of-year proxy
            (dep_time * 60.0) % 60.0,      // minute
            if day_of_week >= 6.0 { 1.0 } else { 0.0 },
        ];
        for c in 0..d {
            values[row * d + c] = cols[c.min(cols.len() - 1)] as Float;
        }
        // delay probability: evening flights, winter months, busy airports,
        // bad carriers
        let mut score = -1.35f64;
        score += carrier_bias[carrier];
        score += airport_bias[origin] * 0.8 + airport_bias[dest] * 0.4;
        score += if (17.0..22.0).contains(&dep_time) { 0.55 } else { 0.0 };
        score += if dep_time < 6.0 { -0.5 } else { 0.0 };
        score += if month == 12.0 || month <= 2.0 { 0.3 } else { 0.0 };
        score += (distance / 2800.0) * 0.2;
        score += rng.next_gaussian() * 0.8; // irreducible noise -> ~75% ceiling
        y[row] = if score > 0.0 { 1.0 } else { 0.0 };
    }
    Dataset::new(DMatrix::dense(values, n, d), y)
}

/// Ranking: query groups with graded relevance 0–4; relevance is a noisy
/// monotone function of a few features.
fn gen_ranking(spec: &DatasetSpec, seed: u64) -> Generated {
    let root = Pcg64::new(seed);
    let mut rng = root.split(7);
    let n = spec.rows;
    let d = spec.cols;
    let mean_group = match spec.task {
        Task::Ranking(g) => g,
        _ => 20,
    };
    let mut make = |n_rows: usize, stream: u64| -> Dataset {
        let mut rng = rng.split(stream);
        let mut values = vec![0.0 as Float; n_rows * d];
        let mut y = vec![0.0 as Float; n_rows];
        let mut groups = vec![0usize];
        let mut row = 0;
        while row < n_rows {
            let g = (mean_group / 2 + rng.gen_range(mean_group)).min(n_rows - row).max(1);
            for _ in 0..g {
                let mut score = 0.0f64;
                for c in 0..d {
                    let v = rng.next_gaussian();
                    values[row * d + c] = v as Float;
                    if c < 5 {
                        score += v * (5 - c) as f64 * 0.3;
                    }
                }
                score += rng.next_gaussian() * 1.2;
                y[row] = ((score + 3.0) / 1.7).clamp(0.0, 4.0).floor() as Float;
                row += 1;
            }
            groups.push(row);
        }
        Dataset::with_groups(DMatrix::dense(values, n_rows, d), y, groups)
    };
    let n_valid = (n as f64 * spec.valid_frac) as usize;
    let train = make(n - n_valid, 100);
    let valid = make(n_valid, 200);
    Generated {
        spec: spec.clone(),
        train,
        valid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = DatasetSpec::higgs_like(500);
        let a = generate(&spec, 42);
        let b = generate(&spec, 42);
        assert_eq!(a.train.y, b.train.y);
        assert_eq!(a.train.x.get(10, 5), b.train.x.get(10, 5));
    }

    #[test]
    fn different_seeds_differ() {
        let spec = DatasetSpec::higgs_like(500);
        let a = generate(&spec, 1);
        let b = generate(&spec, 2);
        assert_ne!(a.train.y, b.train.y);
    }

    #[test]
    fn shapes_match_table1() {
        for (spec, cols) in [
            (DatasetSpec::year_prediction_like(100), 90),
            (DatasetSpec::synthetic_like(100), 100),
            (DatasetSpec::higgs_like(100), 28),
            (DatasetSpec::covtype_like(100), 54),
            (DatasetSpec::bosch_like(100), 968),
            (DatasetSpec::airline_like(100), 13),
        ] {
            let g = generate(&spec, 7);
            assert_eq!(g.train.n_cols(), cols, "{}", spec.name);
            assert_eq!(g.train.n_rows() + g.valid.n_rows(), 100, "{}", spec.name);
        }
    }

    #[test]
    fn binary_labels_are_binary() {
        for spec in [DatasetSpec::higgs_like(300), DatasetSpec::airline_like(300)] {
            let g = generate(&spec, 3);
            assert!(g.train.y.iter().all(|&v| v == 0.0 || v == 1.0));
            let pos: usize = g.train.y.iter().filter(|&&v| v == 1.0).count();
            assert!(pos > 0 && pos < g.train.n_rows());
        }
    }

    #[test]
    fn covtype_classes_in_range() {
        let g = generate(&DatasetSpec::covtype_like(2000), 4);
        let mut seen = [false; 7];
        for &v in &g.train.y {
            let c = v as usize;
            assert!(c < 7);
            seen[c] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 5, "class coverage");
    }

    #[test]
    fn bosch_is_sparse_and_imbalanced() {
        let g = generate(&DatasetSpec::bosch_like(2000), 5);
        let density = g.train.x.density();
        assert!(density > 0.1 && density < 0.3, "density {density}");
        let pos_rate = g.train.y.iter().filter(|&&v| v == 1.0).count() as f64
            / g.train.n_rows() as f64;
        assert!(pos_rate < 0.12, "pos rate {pos_rate}");
    }

    #[test]
    fn year_prediction_label_range() {
        let g = generate(&DatasetSpec::year_prediction_like(1000), 6);
        for &v in &g.train.y {
            assert!((1922.0..=2011.0).contains(&v));
        }
        // labels are not all identical
        let min = g.train.y.iter().cloned().fold(f32::MAX, f32::min);
        let max = g.train.y.iter().cloned().fold(f32::MIN, f32::max);
        assert!(max - min > 20.0);
    }

    #[test]
    fn ranking_groups_cover_rows() {
        let g = generate(&DatasetSpec::ranking_like(1000), 8);
        assert!(!g.train.groups.is_empty());
        assert_eq!(*g.train.groups.last().unwrap(), g.train.n_rows());
        assert!(g.train.y.iter().all(|&v| (0.0..=4.0).contains(&v)));
    }

    #[test]
    fn registry_lookup() {
        assert!(DatasetSpec::by_name("higgs", 10).is_some());
        assert!(DatasetSpec::by_name("Airline", 10).is_some());
        assert!(DatasetSpec::by_name("unknown", 10).is_none());
        assert_eq!(DatasetSpec::table1(0.01).len(), 6);
    }

    #[test]
    fn airline_signal_is_learnable() {
        // delayed rate should vary with departure-time bucket — the signal
        // the trees are supposed to find.
        let g = generate(&DatasetSpec::airline_like(20_000), 11);
        let (mut evening, mut evening_delayed, mut morning, mut morning_delayed) = (0, 0, 0, 0);
        for r in 0..g.train.n_rows() {
            let dep = g.train.x.get(r, 3).unwrap();
            if (17.0..22.0).contains(&dep) {
                evening += 1;
                evening_delayed += (g.train.y[r] == 1.0) as usize;
            } else if dep < 6.0 {
                morning += 1;
                morning_delayed += (g.train.y[r] == 1.0) as usize;
            }
        }
        let ev = evening_delayed as f64 / evening as f64;
        let mo = morning_delayed as f64 / morning as f64;
        assert!(ev > mo + 0.1, "evening {ev} vs morning {mo}");
    }
}
