//! Full-pipeline integration over the AOT artifacts: the Figure-1
//! phases executed through PJRT must agree with the native stack.
//! These tests require `make artifacts`; they self-skip (with a stderr
//! note) when the artifacts are missing so `cargo test` stays runnable
//! before the first build.

use std::sync::Arc;

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, LearnerParams, ObjectiveKind};
use xgb_tpu::runtime::{Artifacts, GradKind, XlaHistBackend, XlaPredictor};

fn artifacts() -> Option<Arc<Artifacts>> {
    match xgb_tpu::runtime::find_artifact_dir(None).map(Artifacts::load) {
        Some(Ok(a)) => Some(Arc::new(a)),
        _ => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

/// §2.5: the gradient artifact reproduces equations (1)-(2) across tile
/// boundaries and for the squared-error objective.
#[test]
fn gradient_artifact_parity() {
    let Some(a) = artifacts() else { return };
    let n = a.manifest.grad_tile + 1234; // forces padding of the tail tile
    let mut rng = xgb_tpu::util::Pcg64::new(99);
    let margins: Vec<f32> = (0..n).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
    let labels: Vec<f32> = (0..n).map(|_| f32::from(rng.next_f32() < 0.4)).collect();

    let (g, h) = a.gradients(GradKind::Logistic, &margins, &labels).unwrap();
    assert_eq!(g.len(), n);
    for i in (0..n).step_by(317) {
        let p = 1.0 / (1.0 + (-margins[i]).exp());
        assert!((g[i] - (p - labels[i])).abs() < 1e-5);
        assert!((h[i] - p * (1.0 - p)).abs() < 1e-5);
    }

    let (g, h) = a.gradients(GradKind::Squared, &margins, &labels).unwrap();
    for i in (0..n).step_by(317) {
        assert!((g[i] - (margins[i] - labels[i])).abs() < 1e-6);
        assert_eq!(h[i], 1.0);
    }
}

/// §2.3 + §2.2: training through the Pallas histogram artifact over
/// *compressed* shards reproduces the native model exactly (same splits).
#[test]
fn xla_training_reproduces_native_model() {
    let Some(a) = artifacts() else { return };
    let g = generate(&DatasetSpec::airline_like(2500), 3);
    let params = LearnerParams {
        objective: ObjectiveKind::BinaryLogistic,
        num_rounds: 2,
        max_depth: 4,
        max_bins: 32,
        compress: true,
        n_devices: 2,
        eval_every: 0,
        ..Default::default()
    };
    let native = Learner::from_params(params.clone())
        .unwrap()
        .train(&g.train, None)
        .unwrap();
    let xla = Learner::from_params(params)
        .unwrap()
        .train_with_backend(&g.train, None, Box::new(XlaHistBackend::new(a)))
        .unwrap();
    // identical structure; leaf values equal to f32-accumulation tolerance
    for (tn, tx) in native.trees[0].iter().zip(xla.trees[0].iter()) {
        assert_eq!(tn.n_nodes(), tx.n_nodes());
        for (a, b) in tn.nodes.iter().zip(tx.nodes.iter()) {
            assert_eq!(a.feature, b.feature);
            assert_eq!(a.left, b.left);
            assert!((a.leaf_value - b.leaf_value).abs() < 1e-4);
        }
    }
}

/// §2.4: the prediction artifact agrees with native traversal on sparse
/// input with missing values and >1 tree chunk.
#[test]
fn predict_artifact_parity_sparse() {
    let Some(a) = artifacts() else { return };
    // 28-feature higgs fits the 32-feature artifact
    let g = generate(&DatasetSpec::higgs_like(3000), 13);
    let params = LearnerParams {
        objective: ObjectiveKind::BinaryLogistic,
        num_rounds: a.manifest.predict_trees + 7, // force chunking
        max_depth: 4,
        max_bins: 32,
        eval_every: 0,
        ..Default::default()
    };
    let b = Learner::from_params(params)
        .unwrap()
        .train(&g.train, None)
        .unwrap();
    let native = b.predict_margins(&g.valid.x).remove(0);
    let xla = XlaPredictor::new(a)
        .predict_margins(&b.trees[0], b.base_score[0], &g.valid.x)
        .unwrap();
    for (i, (n, x)) in native.iter().zip(xla.iter()).enumerate() {
        assert!((n - x).abs() < 1e-3, "row {i}: {n} vs {x}");
    }
}

/// The full Figure-1 loop with every artifact engaged: XLA gradients
/// feeding the XLA histogram backend, scored by the XLA predictor,
/// must produce a learning model.
#[test]
fn full_xla_pipeline_learns() {
    let Some(a) = artifacts() else { return };
    let g = generate(&DatasetSpec::higgs_like(1500), 21);
    let n = g.train.n_rows();

    // manual 2-round boosting loop through artifacts only
    let mut coordinator = xgb_tpu::coordinator::MultiDeviceCoordinator::with_backend(
        &g.train.x,
        xgb_tpu::coordinator::CoordinatorParams {
            max_bins: 32,
            tree: xgb_tpu::tree::TreeParams {
                max_depth: 4,
                ..Default::default()
            },
            ..Default::default()
        },
        Box::new(XlaHistBackend::new(a.clone())),
    )
    .unwrap();

    let mut margins = vec![0.0f32; n];
    let mut trees = Vec::new();
    for _round in 0..2 {
        // §2.5 gradients on "device"
        let (grad, hess) = a
            .gradients(GradKind::Logistic, &margins, &g.train.y)
            .unwrap();
        let gp: Vec<xgb_tpu::GradPair> = grad
            .iter()
            .zip(hess.iter())
            .map(|(&g, &h)| xgb_tpu::GradPair::new(g, h.max(1e-16)))
            .collect();
        // §2.3 tree construction through the Pallas kernel
        let r = coordinator.build_tree(&gp).unwrap();
        for (m, d) in margins.iter_mut().zip(r.deltas.iter()) {
            *m += *d;
        }
        trees.push(r.tree);
    }
    // §2.4 evaluation through the predict artifact
    let preds = XlaPredictor::new(a)
        .predict_margins(&trees, 0.0, &g.valid.x)
        .unwrap();
    let acc = preds
        .iter()
        .zip(g.valid.y.iter())
        .filter(|(&p, &y)| (p > 0.0) == (y == 1.0))
        .count() as f64
        / preds.len() as f64;
    let majority = {
        let pos = g.valid.y.iter().filter(|&&y| y == 1.0).count() as f64 / preds.len() as f64;
        pos.max(1.0 - pos)
    };
    eprintln!("full-xla accuracy {acc:.3} vs majority {majority:.3}");
    assert!(acc > majority - 0.02, "pipeline must at least track majority");
}
