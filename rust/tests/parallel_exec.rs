//! The parallel-engine contract (see `crate::exec`): thread count changes
//! wall-clock only. Bit-identical trees/predictions/metrics across
//! `threads = 1, 2, 8`, and exact chunk-parallel histogram parity across
//! storage formats on dense and sparse fixtures.

use xgb_tpu::compress::CompressedMatrix;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::DMatrix;
use xgb_tpu::exec::ExecContext;
use xgb_tpu::gbm::{Booster, Learner, LearnerParams, MetricKind, ObjectiveKind};
use xgb_tpu::hist::{
    build_histogram_compressed, build_histogram_compressed_par, build_histogram_quantized,
    build_histogram_quantized_par, Histogram,
};
use xgb_tpu::quantile::{HistogramCuts, Quantizer};
use xgb_tpu::util::Pcg64;
use xgb_tpu::{Float, GradPair};

/// The determinism regression the fixed-chunk merge order exists to
/// uphold: same data + same seed + different `threads` must produce
/// bit-identical trees, predictions and eval metrics.
#[test]
fn training_is_bit_identical_across_thread_counts() {
    // > exec::ROW_CHUNK rows per device shard so chunked reduction engages
    let g = generate(&DatasetSpec::higgs_like(22_000), 77);
    let train = |threads: usize| -> Booster {
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            eval_metric: Some(MetricKind::LogLoss),
            num_rounds: 5,
            max_bins: 32,
            max_depth: 4,
            n_devices: 2,
            subsample: 0.9, // the subsample RNG must not observe threads
            threads,
            ..Default::default()
        };
        Learner::from_params(params)
            .unwrap()
            .train(&g.train, Some(&g.valid))
            .unwrap()
    };
    let reference = train(1);
    let ref_preds = reference.predict(&g.valid.x);
    for t in [2usize, 8] {
        let b = train(t);
        assert_eq!(b.trees, reference.trees, "trees must match at threads = {t}");
        assert_eq!(
            b.predict(&g.valid.x),
            ref_preds,
            "predictions must match at threads = {t}"
        );
        assert_eq!(b.eval_history.len(), reference.eval_history.len());
        for (a, r) in b.eval_history.iter().zip(reference.eval_history.iter()) {
            assert_eq!(a.round, r.round);
            assert_eq!(
                a.train.to_bits(),
                r.train.to_bits(),
                "train metric bits at threads = {t}, round {}",
                a.round
            );
            assert_eq!(
                a.valid.map(f64::to_bits),
                r.valid.map(f64::to_bits),
                "valid metric bits at threads = {t}, round {}",
                a.round
            );
        }
    }
}

fn dense_fixture(n: usize, d: usize, seed: u64) -> DMatrix {
    let mut rng = Pcg64::new(seed);
    let vals: Vec<Float> = (0..n * d)
        .map(|_| {
            if rng.next_f64() < 0.1 {
                Float::NAN // missing values exercise the null symbol
            } else {
                rng.next_f32() * 20.0 - 10.0
            }
        })
        .collect();
    DMatrix::dense(vals, n, d)
}

fn sparse_fixture(n: usize, d: usize, seed: u64) -> DMatrix {
    let mut rng = Pcg64::new(seed);
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0);
    for _ in 0..n {
        for col in 0..d {
            if rng.next_f64() < 0.2 {
                indices.push(col as u32);
                values.push(rng.next_f32() * 5.0);
            }
        }
        indptr.push(indices.len());
    }
    DMatrix::csr(indptr, indices, values, n, d)
}

/// Satellite parity check: the chunk-parallel builder over both storage
/// formats vs the serial builder — exact equality, dense and sparse.
#[test]
fn chunk_parallel_histogram_parity_exact() {
    let n = 20_000usize;
    for (name, x) in [
        ("dense", dense_fixture(n, 8, 11)),
        ("sparse", sparse_fixture(n, 30, 13)),
    ] {
        let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let cm = CompressedMatrix::from_quantized(&qm);
        let mut rng = Pcg64::new(29);
        let grads: Vec<GradPair> = (0..n)
            .map(|_| GradPair::new(rng.next_f32() * 2.0 - 1.0, rng.next_f32() + 0.05))
            .collect();
        // full row set and a strided subset (uneven last chunk included)
        for rows in [
            (0..n as u32).collect::<Vec<u32>>(),
            (0..n as u32).step_by(3).collect::<Vec<u32>>(),
        ] {
            let mut serial_q = Histogram::zeros(qm.n_bins);
            build_histogram_quantized(&qm, &grads, &rows, &mut serial_q);
            let mut serial_c = Histogram::zeros(cm.n_bins);
            build_histogram_compressed(&cm, &grads, &rows, &mut serial_c);
            assert_eq!(serial_q, serial_c, "{name}: serial storage parity");
            for t in [1usize, 2, 8] {
                let exec = ExecContext::new(t);
                let mut par_q = Histogram::zeros(qm.n_bins);
                build_histogram_quantized_par(&qm, &grads, &rows, &mut par_q, &exec);
                let mut par_c = Histogram::zeros(cm.n_bins);
                build_histogram_compressed_par(&cm, &grads, &rows, &mut par_c, &exec);
                for (b, (s, p)) in serial_q.bins.iter().zip(par_q.bins.iter()).enumerate() {
                    assert_eq!(
                        s.grad.to_bits(),
                        p.grad.to_bits(),
                        "{name}: quantized grad bin {b} at threads = {t}"
                    );
                    assert_eq!(
                        s.hess.to_bits(),
                        p.hess.to_bits(),
                        "{name}: quantized hess bin {b} at threads = {t}"
                    );
                }
                assert_eq!(par_q, par_c, "{name}: parallel storage parity at threads = {t}");
            }
        }
    }
}

/// Multi-device training with the thread pool engaged must match the
/// quality and structure of serial multi-device training exactly — the
/// device count is the semantic knob, threads are not.
#[test]
fn devices_and_threads_are_orthogonal() {
    let g = generate(&DatasetSpec::year_prediction_like(12_000), 5);
    let train = |n_devices: usize, threads: usize| -> Booster {
        let params = LearnerParams {
            objective: ObjectiveKind::SquaredError,
            num_rounds: 3,
            max_bins: 24,
            max_depth: 3,
            n_devices,
            threads,
            ..Default::default()
        };
        Learner::from_params(params)
            .unwrap()
            .train(&g.train, None)
            .unwrap()
    };
    // fixed device count: threads invisible
    let serial = train(4, 1);
    let pooled = train(4, 8);
    assert_eq!(serial.trees, pooled.trees);
    // and the real engine actually recorded the concurrent phases
    assert!(pooled.build_stats.hist_wall_secs > 0.0);
    assert!(pooled.build_stats.device_wall_secs() > 0.0);
}
