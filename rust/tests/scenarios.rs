//! Scenario-diversity integration suite (ROADMAP #4): the scenario
//! objectives (quantile / Tweedie / AFT) and categorical features train
//! **bit-identically** across every execution strategy — {resident, paged,
//! streamed} × thread counts × device counts — and training continuation
//! (`Learner::resume`) reproduces an uninterrupted run bit for bit,
//! including through a serialization round-trip of the intermediate model.
//!
//! These are the integration-level companions to the per-seam unit tests:
//! a regression anywhere in the ingest → sketch → quantise → grow →
//! predict pipeline that treats one strategy differently from another
//! shows up here as a trees/metric/prediction mismatch.

use xgb_tpu::data::source::DMatrixSource;
use xgb_tpu::data::{DMatrix, Dataset};
use xgb_tpu::gbm::{
    load_model, save_model, AftDistribution, Booster, Learner, LearnerParams, ObjectiveKind,
};
use xgb_tpu::util::Pcg64;
use xgb_tpu::Float;

const N_TRAIN: usize = 300;
const N_VALID: usize = 120;

/// Dense feature block with ~10% missing values.
fn features(rng: &mut Pcg64, n: usize, cols: usize) -> Vec<Float> {
    (0..n * cols)
        .map(|_| {
            if rng.next_f64() < 0.1 {
                Float::NAN
            } else {
                rng.next_f32() * 10.0 - 5.0
            }
        })
        .collect()
}

fn row_signal(xs: &[Float], row: usize, cols: usize) -> Float {
    xs[row * cols..(row + 1) * cols]
        .iter()
        .filter(|v| !v.is_nan())
        .sum::<Float>()
}

/// Real-valued labels (quantile regression).
fn regression_ds(seed: u64, n: usize) -> Dataset {
    let cols = 4;
    let mut rng = Pcg64::new(seed);
    let xs = features(&mut rng, n, cols);
    let y: Vec<Float> = (0..n)
        .map(|r| row_signal(&xs, r, cols) + rng.next_f32() * 2.0)
        .collect();
    Dataset::new(DMatrix::dense(xs, n, cols), y)
}

/// Non-negative labels with a point mass at zero (Tweedie).
fn tweedie_ds(seed: u64, n: usize) -> Dataset {
    let cols = 4;
    let mut rng = Pcg64::new(seed);
    let xs = features(&mut rng, n, cols);
    let y: Vec<Float> = (0..n)
        .map(|r| {
            if rng.next_f64() < 0.3 {
                0.0
            } else {
                (row_signal(&xs, r, cols) + 6.0).max(0.0) + rng.next_f32()
            }
        })
        .collect();
    Dataset::new(DMatrix::dense(xs, n, cols), y)
}

/// Interval labels covering all four censoring shapes (AFT).
fn aft_ds(seed: u64, n: usize) -> Dataset {
    let cols = 4;
    let mut rng = Pcg64::new(seed);
    let xs = features(&mut rng, n, cols);
    let mut lo = Vec::with_capacity(n);
    let mut up = Vec::with_capacity(n);
    for r in 0..n {
        let t = (row_signal(&xs, r, cols) * 0.2).exp() + rng.next_f32();
        match rng.gen_range(4) {
            0 => {
                lo.push(t);
                up.push(t); // uncensored event
            }
            1 => {
                lo.push(t);
                up.push(Float::INFINITY); // right-censored
            }
            2 => {
                lo.push(0.0);
                up.push(t); // left-censored
            }
            _ => {
                lo.push(t);
                up.push(t + 1.0 + rng.next_f32() * 3.0); // interval
            }
        }
    }
    Dataset::with_bounds(DMatrix::dense(xs, n, cols), lo, up)
}

/// Two categorical features (codes 0..7) interleaved with two numeric
/// ones; the label is a membership rule over non-contiguous codes, so a
/// single membership split beats any ordered threshold on the codes.
fn categorical_ds(seed: u64, n: usize) -> Dataset {
    let cols = 4;
    let mut rng = Pcg64::new(seed);
    let mut xs = Vec::with_capacity(n * cols);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c0 = rng.gen_range(7) as Float;
        let f1 = rng.next_f32() * 10.0 - 5.0;
        let c2 = rng.gen_range(5) as Float;
        let f3 = if rng.next_f64() < 0.1 {
            Float::NAN
        } else {
            rng.next_f32() * 4.0
        };
        xs.extend_from_slice(&[c0, f1, c2, f3]);
        let in_set = matches!(c0 as u32, 1 | 4 | 6) || c2 as u32 == 3;
        let noise = rng.next_f64() < 0.08;
        y.push((in_set != noise) as u32 as Float);
    }
    Dataset::new(DMatrix::dense(xs, n, cols), y)
}

fn base_params(objective: ObjectiveKind) -> LearnerParams {
    LearnerParams {
        objective,
        num_rounds: 6,
        max_depth: 3,
        max_bins: 16,
        compress: true,
        eval_every: 1,
        seed: 9,
        ..Default::default()
    }
}

/// Train under one strategy: in-memory when `streamed` is `None`, else
/// through a [`DMatrixSource`] with the given batch size.
fn run(p: &LearnerParams, train: &Dataset, valid: &Dataset, streamed: Option<usize>) -> Booster {
    let mut l = Learner::from_params(p.clone()).unwrap();
    match streamed {
        Some(batch) => {
            let mut src = DMatrixSource::from_dataset(train, batch);
            l.train_from_source(&mut src, Some(valid)).unwrap()
        }
        None => l.train(train, Some(valid)).unwrap(),
    }
}

/// Bit-level equality of everything a scenario observes: trees, base
/// score, per-round metric history, and validation predictions.
fn assert_same(a: &Booster, b: &Booster, valid: &Dataset, ctx: &str) {
    assert_eq!(a.trees, b.trees, "{ctx}: trees");
    assert_eq!(a.base_score, b.base_score, "{ctx}: base score");
    assert_eq!(a.eval_history.len(), b.eval_history.len(), "{ctx}: history length");
    for (x, y) in a.eval_history.iter().zip(b.eval_history.iter()) {
        assert_eq!(x.round, y.round, "{ctx}: round numbering");
        assert_eq!(x.train.to_bits(), y.train.to_bits(), "{ctx} round {}: train", x.round);
        assert_eq!(
            x.valid.map(f64::to_bits),
            y.valid.map(f64::to_bits),
            "{ctx} round {}: valid",
            x.round
        );
    }
    let (pa, pb) = (a.predict(&valid.x), b.predict(&valid.x));
    assert_eq!(pa.len(), pb.len(), "{ctx}: prediction count");
    for (i, (u, v)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: prediction {i}");
    }
}

/// Every scenario (new objectives + categorical) × {resident, paged,
/// streamed} × threads {1, 4} × devices {1, 3} produces bit-identical
/// trees, metric histories and predictions.
#[test]
fn scenario_objectives_and_categorical_bit_identical_across_strategies() {
    let quantile = {
        let mut p = base_params(ObjectiveKind::QuantileReg);
        p.quantile_alpha = 0.9;
        p
    };
    let tweedie = {
        let mut p = base_params(ObjectiveKind::Tweedie);
        p.tweedie_variance_power = 1.3;
        p
    };
    let aft_normal = base_params(ObjectiveKind::SurvivalAft);
    let aft_logistic = {
        let mut p = base_params(ObjectiveKind::SurvivalAft);
        p.aft_distribution = AftDistribution::Logistic;
        p.aft_sigma = 0.7;
        p
    };
    let categorical = {
        let mut p = base_params(ObjectiveKind::BinaryLogistic);
        p.categorical_features = vec![0, 2];
        p
    };
    let scenarios: Vec<(&str, LearnerParams, Dataset, Dataset)> = vec![
        ("quantile", quantile, regression_ds(31, N_TRAIN), regression_ds(32, N_VALID)),
        ("tweedie", tweedie, tweedie_ds(41, N_TRAIN), tweedie_ds(42, N_VALID)),
        ("aft-normal", aft_normal, aft_ds(51, N_TRAIN), aft_ds(52, N_VALID)),
        ("aft-logistic", aft_logistic, aft_ds(61, N_TRAIN), aft_ds(62, N_VALID)),
        ("categorical", categorical, categorical_ds(71, N_TRAIN), categorical_ds(72, N_VALID)),
    ];
    for (name, base, train, valid) in &scenarios {
        let reference = run(base, train, valid, None);
        assert!(!reference.trees[0].is_empty(), "{name}: no trees trained");
        for devices in [1usize, 3] {
            for threads in [1usize, 4] {
                let mut p = base.clone();
                p.n_devices = devices;
                p.threads = threads;
                let mut paged = p.clone();
                paged.max_resident_pages = 2;
                paged.page_rows = 64;
                let ctx = |s: &str| format!("{name} {s} devices={devices} threads={threads}");
                assert_same(&run(&p, train, valid, None), &reference, valid, &ctx("resident"));
                assert_same(&run(&paged, train, valid, None), &reference, valid, &ctx("paged"));
                assert_same(&run(&p, train, valid, Some(7)), &reference, valid, &ctx("streamed"));
            }
        }
    }
}

/// `train(5) → serialize → reload → resume(5)` equals `train(10)` bit for
/// bit — trees, round-numbered metric history, predictions, and the saved
/// model bytes — across threads × devices, in-memory and streamed, with
/// row and column subsampling active so the continuation's rng
/// fast-forward is exercised too.
#[test]
fn resume_reproduces_uninterrupted_run_bit_for_bit() {
    let train = categorical_ds(81, N_TRAIN);
    let valid = categorical_ds(82, N_VALID);
    for devices in [1usize, 3] {
        for threads in [1usize, 4] {
            for streamed in [None, Some(7usize)] {
                let mut p = base_params(ObjectiveKind::BinaryLogistic);
                p.categorical_features = vec![0, 2];
                p.n_devices = devices;
                p.threads = threads;
                p.num_rounds = 10;
                p.subsample = 0.8;
                p.colsample_bytree = 0.75;
                let ctx = format!(
                    "devices={devices} threads={threads} streamed={}",
                    streamed.is_some()
                );
                let full = run(&p, &train, &valid, streamed);

                let mut p5 = p.clone();
                p5.num_rounds = 5;
                let part1 = run(&p5, &train, &valid, streamed);
                // the resumed run consumes the *persisted* artifact, so the
                // frozen-cuts + shaping-param round-trip is in the loop
                let mut bytes = Vec::new();
                save_model(&part1, &mut bytes).unwrap();
                let prior = load_model(&bytes[..]).unwrap();

                let mut l2 = Learner::from_params(p5.clone()).unwrap();
                let combined = match streamed {
                    Some(batch) => {
                        let mut src = DMatrixSource::from_dataset(&train, batch);
                        l2.resume_from_source(&prior, &mut src, Some(&valid)).unwrap()
                    }
                    None => l2.resume(&prior, &train, Some(&valid)).unwrap(),
                };

                assert_eq!(combined.trees, full.trees, "{ctx}: trees");
                assert_eq!(combined.base_score, full.base_score, "{ctx}: base score");
                // the continuation records global rounds 6..=10, matching
                // the tail of the uninterrupted history exactly
                assert_eq!(combined.eval_history.len(), 5, "{ctx}: resumed history length");
                for (c, f) in combined.eval_history.iter().zip(full.eval_history[5..].iter()) {
                    assert_eq!(c.round, f.round, "{ctx}: round numbering");
                    assert_eq!(c.train.to_bits(), f.train.to_bits(), "{ctx} round {}", c.round);
                    assert_eq!(
                        c.valid.map(f64::to_bits),
                        f.valid.map(f64::to_bits),
                        "{ctx} round {}",
                        c.round
                    );
                }
                let (pf, pc) = (full.predict(&valid.x), combined.predict(&valid.x));
                for (i, (u, v)) in pf.iter().zip(pc.iter()).enumerate() {
                    assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: prediction {i}");
                }
                // 5 + resume-5 and train-10 persist to byte-identical files
                let (mut a, mut b) = (Vec::new(), Vec::new());
                save_model(&full, &mut a).unwrap();
                save_model(&combined, &mut b).unwrap();
                assert_eq!(a, b, "{ctx}: saved models must be byte-identical");
            }
        }
    }
}

/// Mismatched continuation parameters are rejected up front with a clear
/// error instead of silently training against a different grid.
#[test]
fn resume_rejects_mismatched_params() {
    let train = regression_ds(91, N_TRAIN);
    let valid = regression_ds(92, N_VALID);
    let mut p = base_params(ObjectiveKind::QuantileReg);
    p.quantile_alpha = 0.9;
    let prior = run(&p, &train, &valid, None);

    let resume_err = |params: LearnerParams| -> String {
        match Learner::from_params(params)
            .unwrap()
            .resume(&prior, &train, Some(&valid))
        {
            Ok(_) => panic!("resume with mismatched params must fail"),
            Err(e) => format!("{e:#}"),
        }
    };

    // different objective
    let mut other = base_params(ObjectiveKind::SquaredError);
    other.num_rounds = 2;
    let msg = resume_err(other);
    assert!(msg.contains("objective"), "{msg}");

    // same objective, different shaping parameter
    let mut shifted = p.clone();
    shifted.quantile_alpha = 0.5;
    let msg = resume_err(shifted);
    assert!(msg.contains("quantile_alpha"), "{msg}");

    // different bin budget: the frozen grid cannot be re-derived
    let mut coarser = p.clone();
    coarser.max_bins = 8;
    let msg = resume_err(coarser);
    assert!(msg.contains("max_bins"), "{msg}");
}

/// Categorical membership splits survive serialization and route
/// identically through the float, bin-translated and flat-serve paths.
#[test]
fn categorical_model_round_trips_through_serialization_and_flat_serving() {
    use xgb_tpu::exec::ExecContext;
    use xgb_tpu::predict::quantised::{BinForest, QuantisedBatch};
    use xgb_tpu::serve::FlatBatch;

    let train = categorical_ds(101, N_TRAIN);
    let valid = categorical_ds(102, N_VALID);
    let mut p = base_params(ObjectiveKind::BinaryLogistic);
    p.categorical_features = vec![0, 2];
    let booster = run(&p, &train, &valid, None);

    let has_cat = booster
        .trees
        .iter()
        .flatten()
        .any(|t| t.nodes.iter().any(|nd| nd.cats != 0));
    assert!(has_cat, "categorical training must produce membership splits");

    // serialization round-trip: cat nodes + categorical cut flags persist,
    // and the reloaded model predicts bit-identically
    let mut bytes = Vec::new();
    save_model(&booster, &mut bytes).unwrap();
    let text = String::from_utf8(bytes.clone()).unwrap();
    assert!(text.contains(" cat "), "membership nodes persist as `cat` records");
    assert!(text.contains("cuts categorical ="), "categorical flags persist with the cuts");
    let reloaded = load_model(&bytes[..]).unwrap();
    assert_eq!(reloaded.trees, booster.trees, "trees round-trip");
    let (pa, pb) = (booster.predict(&valid.x), reloaded.predict(&valid.x));
    for (i, (u, v)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "reloaded prediction {i}");
    }

    // flat-serve parity: the SoA arena routes membership splits exactly
    // like per-row float traversal, including missing and out-of-vocab
    let cuts = booster.cuts.as_ref().expect("trained booster carries cuts");
    let bf = BinForest::from_trees(&booster.trees, cuts);
    let flat = bf.flatten().unwrap();
    let qb = QuantisedBatch::from_dmatrix(&valid.x, cuts, 0).unwrap();
    let fb = FlatBatch::from_quantised(&qb, valid.x.n_cols());
    let exec = ExecContext::new(2);
    let margins = flat.predict_margins(&booster.base_score, &fb, &exec);
    for r in 0..valid.x.n_rows() {
        let mut want = booster.base_score[0];
        for t in &booster.trees[0] {
            want += t.nodes[t.leaf_for_row(&valid.x, r)].leaf_value;
        }
        assert_eq!(margins[0][r].to_bits(), want.to_bits(), "flat margin row {r}");
    }
}
