//! Compressed end-to-end prediction parity suite: predictions, leaf
//! indices and eval metrics computed straight from the packed ELLPACK
//! representation (resident shards, spilled pages, streamed batches)
//! must be **bit-identical** to the float traversal — across
//! {dense CSV, sparse LibSVM, multiclass, ranking} × page sizes
//! {1-page, 64-row} × budgets {1,3} × threads {1,4} × devices {1,3},
//! including values exactly on cut boundaries and NaN/missing rows
//! (default-direction traversal). Also pins the streaming prediction
//! peak-memory contract (O(batch_rows × n_cols) transient) and the
//! paged path's `max_resident_pages` residency bound.

use std::path::PathBuf;

use xgb_tpu::coordinator::device::ShardStorage;
use xgb_tpu::coordinator::MultiDeviceCoordinator;
use xgb_tpu::data::source::DMatrixSource;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::{load_csv, load_libsvm, save_csv, save_libsvm, DMatrix, Dataset};
use xgb_tpu::data::{CsvSource, LibsvmSource};
use xgb_tpu::gbm::{Booster, Learner, LearnerParams, ObjectiveKind};
use xgb_tpu::predict;
use xgb_tpu::Float;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("xgb_tpu_cpred_{name}_{}", std::process::id()))
}

fn params(objective: ObjectiveKind, threads: usize, devices: usize) -> LearnerParams {
    LearnerParams {
        objective,
        num_rounds: 4,
        max_depth: 3,
        max_bins: 16,
        n_devices: devices,
        threads,
        compress: true,
        eval_every: 1,
        ..Default::default()
    }
}

fn train(p: LearnerParams, ds: &Dataset, valid: Option<&Dataset>) -> Booster {
    Learner::from_params(p).unwrap().train(ds, valid).unwrap()
}

/// Float-path reference: margins + leaf indices over the raw matrix.
fn float_reference(b: &Booster, x: &DMatrix) -> (Vec<Vec<Float>>, Vec<Vec<u32>>) {
    let margins = predict::predict_margins(&b.trees, &b.base_score, x);
    let leaves = predict::predict_leaf_indices(&b.trees[0], x);
    (margins, leaves)
}

fn assert_margins_eq(a: &[Vec<Float>], b: &[Vec<Float>], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: group count");
    for (k, (ga, gb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(ga.len(), gb.len(), "{ctx}: group {k} length");
        for (i, (x, y)) in ga.iter().zip(gb.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: group {k} row {i}: {x} vs {y}"
            );
        }
    }
}

/// The core sweep: train once per (threads, devices), then require the
/// coordinator's quantised shard prediction — resident AND paged at
/// every (page size, budget) — to reproduce the float path bit for bit.
fn sweep_storage_parity(ds: &Dataset, objective: ObjectiveKind, ctx_name: &str) {
    for devices in [1usize, 3] {
        for threads in [1usize, 4] {
            let p = params(objective, threads, devices);
            let booster = train(p.clone(), ds, None);
            let (float_margins, float_leaves) = float_reference(&booster, &ds.x);

            // resident packed shards
            let resident = MultiDeviceCoordinator::from_dmatrix(&ds.x, p.coordinator_params())
                .unwrap();
            assert_eq!(
                Some(&resident.cuts),
                booster.cuts.as_ref(),
                "{ctx_name}: coordinator and model must share cuts"
            );
            let (m, stats) = resident
                .predict_margins(&booster.trees, &booster.base_score)
                .unwrap();
            assert_margins_eq(&float_margins, &m, &format!("{ctx_name} resident d={devices} t={threads}"));
            assert!(stats.predict_wall_secs >= 0.0);
            assert_eq!(stats.pages_loaded, 0, "resident prediction loads no pages");
            let (l, _) = resident.predict_leaf_indices(&booster.trees[0]).unwrap();
            assert_eq!(float_leaves, l, "{ctx_name} resident leaves d={devices} t={threads}");

            // paged shards: 1-page (everything in one page) and 64-row
            let shard_rows = ds.n_rows().div_ceil(devices);
            for page_rows in [shard_rows + 1, 64usize] {
                for budget in [1usize, 3] {
                    let mut pp = p.coordinator_params();
                    pp.max_resident_pages = budget;
                    pp.page_rows = page_rows;
                    let paged = MultiDeviceCoordinator::from_dmatrix(&ds.x, pp).unwrap();
                    let ctx = format!(
                        "{ctx_name} paged d={devices} t={threads} page_rows={page_rows} budget={budget}"
                    );
                    let (pm, pstats) = paged
                        .predict_margins(&booster.trees, &booster.base_score)
                        .unwrap();
                    assert_margins_eq(&float_margins, &pm, &ctx);
                    assert!(pstats.pages_loaded > 0, "{ctx}: must read spilled pages");
                    // residency bound: budget x largest page of any shard
                    let max_page = paged
                        .devices
                        .iter()
                        .map(|d| match &d.storage {
                            ShardStorage::Paged(ps) => ps.max_page_bytes(),
                            _ => panic!("expected paged storage"),
                        })
                        .max()
                        .unwrap();
                    assert!(
                        pstats.peak_resident_page_bytes <= budget * max_page,
                        "{ctx}: peak {} > {budget} x {max_page}",
                        pstats.peak_resident_page_bytes
                    );
                    let (pl, _) = paged.predict_leaf_indices(&booster.trees[0]).unwrap();
                    assert_eq!(float_leaves, pl, "{ctx}: leaves");
                }
            }
        }
    }
}

#[test]
fn dense_csv_storage_parity() {
    // text round-trip so every float is exactly what a file reader sees
    let g = generate(&DatasetSpec::airline_like(500), 41);
    let path = tmp("dense.csv");
    save_csv(&g.train, &path).unwrap();
    let ds = load_csv(&path, 0, false).unwrap();
    sweep_storage_parity(&ds, ObjectiveKind::BinaryLogistic, "dense-csv");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sparse_libsvm_storage_parity() {
    let g = generate(&DatasetSpec::bosch_like(450), 43);
    let path = tmp("sparse.libsvm");
    save_libsvm(&g.train, &path).unwrap();
    let ds = load_libsvm(&path).unwrap();
    sweep_storage_parity(&ds, ObjectiveKind::BinaryLogistic, "sparse-libsvm");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn multiclass_storage_parity() {
    let g = generate(&DatasetSpec::covtype_like(600), 45);
    let mut p = params(ObjectiveKind::MultiSoftmax, 4, 3);
    p.num_class = 7;
    let booster = train(p.clone(), &g.train, None);
    assert_eq!(booster.trees.len(), 7);
    let (float_margins, float_leaves) = float_reference(&booster, &g.train.x);
    let mut pp = p.coordinator_params();
    pp.max_resident_pages = 2;
    pp.page_rows = 64;
    let paged = MultiDeviceCoordinator::from_dmatrix(&g.train.x, pp).unwrap();
    let (m, _) = paged
        .predict_margins(&booster.trees, &booster.base_score)
        .unwrap();
    assert_margins_eq(&float_margins, &m, "multiclass paged");
    let (l, _) = paged.predict_leaf_indices(&booster.trees[0]).unwrap();
    assert_eq!(float_leaves, l);
    // transformed predictions (class ids) agree through the stream path
    let mut src = DMatrixSource::from_dataset(&g.train, 97);
    let streamed = booster.predict_from_source(&mut src).unwrap();
    assert_eq!(booster.predict(&g.train.x), streamed);
}

#[test]
fn ranking_stream_eval_parity() {
    // qid groups ride the stream; ndcg via the compressed path must
    // equal the float evaluation exactly
    let g = generate(&DatasetSpec::ranking_like(500), 47);
    let path = tmp("rank.libsvm");
    save_libsvm(&g.train, &path).unwrap();
    let ds = load_libsvm(&path).unwrap();
    let booster = train(params(ObjectiveKind::RankPairwise, 1, 1), &ds, None);
    let float_ndcg = booster.evaluate(&ds, "ndcg").unwrap();
    for batch_rows in [33usize, 1024] {
        let mut src = LibsvmSource::open(&path, batch_rows).unwrap();
        let stream_ndcg = booster.evaluate_from_source(&mut src, "ndcg").unwrap();
        assert_eq!(
            float_ndcg.to_bits(),
            stream_ndcg.to_bits(),
            "batch_rows={batch_rows}: {float_ndcg} vs {stream_ndcg}"
        );
    }
    let mut src = LibsvmSource::open(&path, 61).unwrap();
    let (paged_ndcg, clamped) = booster.evaluate_paged(&mut src, "ndcg", 64, 2).unwrap();
    assert_eq!(float_ndcg.to_bits(), paged_ndcg.to_bits());
    assert_eq!(clamped, 0, "training-range input never clamps");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn streaming_prediction_matches_and_stays_bounded() {
    // dense CSV streamed straight from the file — predictions must be
    // bit-identical to the in-memory float path for every batch size and
    // thread count, with transient bytes bounded by the batch
    let g = generate(&DatasetSpec::airline_like(700), 49);
    let path = tmp("stream.csv");
    save_csv(&g.train, &path).unwrap();
    let ds = load_csv(&path, 0, false).unwrap();
    for threads in [1usize, 4] {
        let mut p = params(ObjectiveKind::BinaryLogistic, threads, 2);
        p.num_rounds = 3;
        let booster = train(p, &ds, None);
        let float = booster.predict(&ds.x);
        for batch_rows in [7usize, 64, ds.n_rows()] {
            let mut src = CsvSource::open(&path, 0, false, batch_rows).unwrap();
            let (preds, sm) = booster.predict_stream(&mut src).unwrap();
            assert_eq!(
                float, preds,
                "threads={threads} batch_rows={batch_rows}: streamed predictions"
            );
            assert_eq!(sm.n_rows, ds.n_rows());
            // O(batch_rows x n_cols) transient: floats (4B) + unclamped
            // bins (4B) per cell, plus small per-row overhead
            let bound = batch_rows * ds.n_cols() * 8 + (batch_rows + 1) * 16;
            assert!(
                sm.peak_transient_bytes <= bound,
                "threads={threads} batch_rows={batch_rows}: {} > {bound}",
                sm.peak_transient_bytes
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn paged_streaming_prediction_matches() {
    // LibSVM file -> pack_source spill -> paged traversal: predictions
    // identical to float; residency budget respected
    let g = generate(&DatasetSpec::bosch_like(400), 51);
    let path = tmp("paged.libsvm");
    save_libsvm(&g.train, &path).unwrap();
    let ds = load_libsvm(&path).unwrap();
    let booster = train(params(ObjectiveKind::BinaryLogistic, 4, 1), &ds, None);
    let float = booster.predict(&ds.x);
    for (page_rows, budget) in [(64usize, 1usize), (64, 3), (ds.n_rows() + 1, 1)] {
        let mut src = LibsvmSource::open(&path, 53).unwrap();
        let (preds, packed) = booster.predict_paged(&mut src, page_rows, budget).unwrap();
        assert_eq!(float, preds, "page_rows={page_rows} budget={budget}");
        assert_eq!(packed.labels, ds.y);
        assert_eq!(packed.clamped_values, 0, "training-range input never clamps");
        let stats = packed.store.take_round_stats();
        assert!(stats.pages_loaded > 0);
        assert!(
            stats.peak_resident_bytes <= budget * packed.store.max_page_bytes(),
            "page_rows={page_rows} budget={budget}: {} > {budget} x {}",
            stats.peak_resident_bytes,
            packed.store.max_page_bytes()
        );
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn cut_boundary_and_missing_rows_route_identically() {
    // rows whose values fall exactly ON cut values (the v < cut edge)
    // and rows that are entirely/partially missing (default-direction
    // traversal) — quantised vs float must agree everywhere
    let n = 400usize;
    let mut vals = Vec::with_capacity(n * 3);
    let mut rng = 13u64;
    for i in 0..n {
        // feature 0: small integer grid -> many values sit exactly on cuts
        vals.push((i % 8) as Float);
        // feature 1: some NaNs
        rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
        vals.push(if rng % 5 == 0 {
            Float::NAN
        } else {
            ((rng >> 33) % 100) as Float / 10.0
        });
        // feature 2: constant (single-bin feature)
        vals.push(1.0);
    }
    let x = DMatrix::dense(vals, n, 3);
    let y: Vec<Float> = (0..n)
        .map(|i| if (i % 8) >= 4 { 1.0 } else { 0.0 })
        .collect();
    let ds = Dataset::new(x, y);
    let booster = train(params(ObjectiveKind::BinaryLogistic, 1, 1), &ds, None);
    let (float_margins, float_leaves) = float_reference(&booster, &ds.x);

    // quantised values of feature 0 land exactly on cut values: verify
    // the fixture actually exercises the boundary
    let cuts = booster.cuts.as_ref().unwrap();
    let f0 = cuts.feature_cuts(0);
    assert!(
        (0..8).any(|v| f0.contains(&(v as Float))),
        "fixture should put values on cut boundaries: cuts {f0:?}"
    );

    let mut pp = params(ObjectiveKind::BinaryLogistic, 1, 1).coordinator_params();
    pp.max_resident_pages = 1;
    pp.page_rows = 64;
    let paged = MultiDeviceCoordinator::from_dmatrix(&ds.x, pp).unwrap();
    let (m, _) = paged
        .predict_margins(&booster.trees, &booster.base_score)
        .unwrap();
    assert_margins_eq(&float_margins, &m, "cut-boundary paged");
    let (l, _) = paged.predict_leaf_indices(&booster.trees[0]).unwrap();
    assert_eq!(float_leaves, l);

    let mut src = DMatrixSource::from_dataset(&ds, 37);
    let streamed = booster.predict_from_source(&mut src).unwrap();
    assert_eq!(booster.predict(&ds.x), streamed);
}

#[test]
fn in_training_eval_is_bit_identical_to_float_scoring() {
    // the boosting loop's per-round validation metric now comes off the
    // quantised path; recomputing the final valid metric through the
    // float path must give the exact same number
    let g = generate(&DatasetSpec::higgs_like(900), 53);
    for devices in [1usize, 3] {
        for threads in [1usize, 4] {
            let booster = train(
                params(ObjectiveKind::BinaryLogistic, threads, devices),
                &g.train,
                Some(&g.valid),
            );
            let recorded = booster.eval_history.last().unwrap().valid.unwrap();
            let float = booster.evaluate(&g.valid, "accuracy").unwrap();
            assert_eq!(
                recorded.to_bits(),
                float.to_bits(),
                "devices={devices} threads={threads}: {recorded} vs {float}"
            );
        }
    }
}

#[test]
fn leaf_indices_respect_threads_knob() {
    // the Booster surface honours `threads` and is bit-identical at
    // every budget (the predict/mod.rs unit test pins the free function)
    let g = generate(&DatasetSpec::higgs_like(20_000), 57);
    let reference = train(params(ObjectiveKind::BinaryLogistic, 1, 1), &g.train, None);
    let serial = reference.predict_leaf_indices(&g.train.x);
    for threads in [2usize, 8] {
        let mut b = train(params(ObjectiveKind::BinaryLogistic, 1, 1), &g.train, None);
        assert_eq!(b.trees, reference.trees, "same config -> same trees");
        b.params.threads = threads;
        assert_eq!(
            b.predict_leaf_indices(&g.train.x),
            serial,
            "threads = {threads}"
        );
    }
}
