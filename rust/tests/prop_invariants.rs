//! Property-based tests over the core invariants (DESIGN.md §6), using the
//! hand-rolled harness in `xgb_tpu::util::prop`.

use xgb_tpu::comm::{ring_allreduce, serial_allreduce};
use xgb_tpu::compress::CompressedMatrix;
use xgb_tpu::data::DMatrix;
use xgb_tpu::hist::{build_histogram_quantized, GradPairF64, Histogram};
use xgb_tpu::quantile::{HistogramCuts, Quantizer, WQSummary};
use xgb_tpu::tree::partitioner::BinSource;
use xgb_tpu::tree::{RowPartitioner, SplitEvaluator, TreeParams};
use xgb_tpu::util::prop::{check, Gen};
use xgb_tpu::{Float, GradPair};

/// Sketch error bound: a pruned summary's rank uncertainty stays within
/// the theoretical budget, and queried quantiles land within eps·n ranks.
#[test]
fn prop_sketch_error_bound() {
    check(0x5e7c4, 40, |g: &mut Gen| {
        let n = g.int(100, 5000);
        let limit = g.int(16, 128);
        let values: Vec<Float> = (0..n).map(|_| g.f32(-100.0, 100.0)).collect();
        let mut b = xgb_tpu::quantile::sketch::SketchBuilder::new(limit);
        for &v in &values {
            b.push(v, 1.0);
        }
        let s = b.finish();
        s.check_invariants();
        assert!((s.total_weight() - n as f64).abs() < 1e-6);
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // generous eps: merge-prune rounds compound; bound with factor 6
        let eps = 6.0 / limit as f64;
        for k in 1..10 {
            let d = n as f64 * k as f64 / 10.0;
            let q = s.query(d).unwrap();
            let rank = sorted.partition_point(|&v| v < q) as f64;
            assert!(
                (rank - d).abs() <= eps * n as f64 + 2.0,
                "n={n} limit={limit} decile {k}: rank {rank} target {d}"
            );
        }
    });
}

/// Merging two exact summaries equals the exact summary of the union.
#[test]
fn prop_sketch_combine_exact() {
    check(0xc0b1e5, 50, |g: &mut Gen| {
        let n1 = g.int(1, 200);
        let n2 = g.int(1, 200);
        let a: Vec<Float> = (0..n1).map(|_| g.f32(-10.0, 10.0)).collect();
        let b: Vec<Float> = (0..n2).map(|_| g.f32(-10.0, 10.0)).collect();
        let sa = WQSummary::from_values(&a);
        let sb = WQSummary::from_values(&b);
        let combined = sa.combine(&sb);
        combined.check_invariants();
        let mut all = a.clone();
        all.extend_from_slice(&b);
        let exact = WQSummary::from_values(&all);
        assert_eq!(combined.entries.len(), exact.entries.len());
        for (x, y) in combined.entries.iter().zip(exact.entries.iter()) {
            assert_eq!(x.value, y.value);
            assert!((x.rmin - y.rmin).abs() < 1e-9);
            assert!((x.rmax - y.rmax).abs() < 1e-9);
        }
    });
}

/// Bit-pack/unpack round-trips exactly for arbitrary shapes & alphabets.
#[test]
fn prop_compression_roundtrip() {
    check(0xc0de, 60, |g: &mut Gen| {
        let n_rows = g.int(1, 300);
        let stride = g.int(1, 24);
        let bits = g.int(1, 18);
        let n_bins = g.int(1, 1 << bits);
        let bins: Vec<u32> = (0..n_rows * stride)
            .map(|_| g.int(0, n_bins) as u32) // includes null == n_bins
            .collect();
        let qm = xgb_tpu::quantile::QuantizedMatrix {
            bins: bins.clone(),
            n_rows,
            n_features: stride,
            row_stride: stride,
            n_bins,
            dense: true,
        };
        let cm = CompressedMatrix::from_quantized(&qm);
        assert_eq!(cm.decode().bins, bins);
    });
}

/// Ring all-reduce equals the serial sum for arbitrary p and n.
#[test]
fn prop_ring_allreduce_equals_serial() {
    check(0xa11d, 60, |g: &mut Gen| {
        let p = g.int(1, 12);
        let n = g.int(1, 500);
        let bufs: Vec<Vec<f64>> = (0..p)
            .map(|_| (0..n).map(|_| g.f64(-5.0, 5.0)).collect())
            .collect();
        let mut ring = bufs.clone();
        let mut serial = bufs;
        ring_allreduce(&mut ring);
        serial_allreduce(&mut serial);
        for (rb, sb) in ring.iter().zip(serial.iter()) {
            for (r, s) in rb.iter().zip(sb.iter()) {
                assert!((r - s).abs() < 1e-9, "p={p} n={n}");
            }
        }
    });
}

/// The real TCP ring (loopback, one thread per rank) produces buffers
/// **bit-identical** to the in-process simulation over the same
/// per-rank inputs — across node counts 2..=4, uneven n (including
/// n < p, i.e. empty chunks), and both wire encodings. This is the
/// determinism contract that makes distributed trees byte-equal to
/// single-process ones.
#[test]
fn prop_wire_ring_matches_simulation_bitwise() {
    use std::net::TcpListener;
    use xgb_tpu::comm::{WirePayload, WireRing};

    check(0x317e, 10, |g: &mut Gen| {
        let p = g.int(2, 4);
        let n = g.int(0, 97);
        let payload = if g.int(0, 1) == 0 {
            WirePayload::Quant
        } else {
            WirePayload::Raw
        };
        // histogram-shaped values: f32-origin sums with empty bins
        let bufs: Vec<Vec<f64>> = (0..p)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        if g.int(0, 4) == 0 {
                            0.0
                        } else {
                            g.f32(-3.0, 3.0) as f64
                        }
                    })
                    .collect()
            })
            .collect();
        let mut expect = bufs.clone();
        ring_allreduce(&mut expect);

        // bind every rank's listener at port 0 first so the shared peer
        // list carries the real ephemeral ports before any rank dials
        let listeners: Vec<TcpListener> = (0..p)
            .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind loopback"))
            .collect();
        let peers: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(r, listener)| {
                let peers = peers.clone();
                let mut buf = bufs[r].clone();
                std::thread::spawn(move || {
                    let mut ring =
                        WireRing::establish_with_listener(r, &peers, listener, payload)
                            .expect("ring assembly");
                    let stats = ring.allreduce(&mut buf).expect("wire allreduce");
                    (buf, stats)
                })
            })
            .collect();
        for (r, h) in handles.into_iter().enumerate() {
            let (got, stats) = h.join().expect("rank thread panicked");
            assert_eq!(stats.steps, 2 * (p - 1));
            assert_eq!(got.len(), expect[r].len());
            for (i, (gv, wv)) in got.iter().zip(expect[r].iter()).enumerate() {
                assert_eq!(
                    gv.to_bits(),
                    wv.to_bits(),
                    "p={p} n={n} payload={payload} rank={r} elem {i}: wire {gv} vs sim {wv}"
                );
            }
        }
    });
}

/// Partitioning preserves the row multiset and routes by bin threshold.
#[test]
fn prop_partition_preserves_rows() {
    check(0x9a47, 40, |g: &mut Gen| {
        let n = g.int(10, 400);
        let cols = g.int(1, 5);
        let vals: Vec<Float> = (0..n * cols)
            .map(|_| {
                if g.bool(0.1) {
                    Float::NAN
                } else {
                    g.f32(-5.0, 5.0)
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, cols);
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let feature = g.int(0, cols - 1);
        let lo = cuts.ptrs[feature];
        let hi = cuts.ptrs[feature + 1];
        if hi - lo < 2 {
            return;
        }
        let split_bin = lo + g.int(0, (hi - lo - 1) as usize) as u32;
        let split = xgb_tpu::tree::SplitCandidate {
            feature: feature as u32,
            split_bin,
            threshold: cuts.cut_of_bin(split_bin),
            default_left: g.bool(0.5),
            gain: 1.0,
            left_sum: GradPairF64::default(),
            right_sum: GradPairF64::default(),
            categories: 0,
            cat_bins: 0,
        };
        let mut part = RowPartitioner::new(n);
        let src = BinSource::Quantized(&qm);
        let (nl, nr) = part.apply_split(0, &split, 1, 2, &src, &cuts);
        assert_eq!(nl + nr, n);
        let mut all: Vec<u32> = part.node_rows(1).iter().chain(part.node_rows(2)).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n as u32).collect::<Vec<_>>());
        // routing agrees with raw values
        for &r in part.node_rows(1) {
            match x.get(r as usize, feature) {
                Some(v) => assert!(v < split.threshold, "left row must be below cut"),
                None => assert!(split.default_left),
            }
        }
    });
}

/// Histogram-based best split gain matches brute force over raw values.
#[test]
fn prop_split_matches_brute_force() {
    check(0x59117, 25, |g: &mut Gen| {
        let n = g.int(20, 150);
        let cols = g.int(1, 3);
        let vals: Vec<Float> = (0..n * cols)
            .map(|_| {
                if g.bool(0.15) {
                    Float::NAN
                } else {
                    g.f32(-3.0, 3.0)
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, cols);
        let grads: Vec<GradPair> = g.grad_pairs(n);
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hist);
        let node_sum = grads.iter().fold(GradPairF64::default(), |a, gp| {
            a + GradPairF64::from_single(*gp)
        });
        let ev = SplitEvaluator::new(TreeParams {
            min_child_weight: 0.0,
            ..Default::default()
        });
        let hist_gain = ev.evaluate(&hist, &cuts, node_sum).map(|s| s.gain).unwrap_or(0.0);

        // brute force over the same candidate cuts
        let mut brute = 0.0f64;
        for f in 0..cols {
            for cut in cuts.feature_cuts(f) {
                for missing_left in [false, true] {
                    let mut left = GradPairF64::default();
                    for r in 0..n {
                        let goes_left = match x.get(r, f) {
                            Some(v) => v < *cut,
                            None => missing_left,
                        };
                        if goes_left {
                            left += GradPairF64::from_single(grads[r]);
                        }
                    }
                    let right = node_sum - left;
                    brute = brute.max(ev.split_gain(node_sum, left, right));
                }
            }
        }
        assert!(
            (hist_gain - brute).abs() < 1e-9,
            "hist {hist_gain} vs brute {brute}"
        );
    });
}

/// Bin-threshold translation round-trips: for random trees over random
/// cut grids, translating every float threshold with `threshold_to_bin`
/// and routing rows by `bin < translated` visits exactly the leaves the
/// float traversal visits — for every row (incl. NaN/missing and values
/// exactly on cut boundaries), and for thresholds below the first cut /
/// above the last (sentinel) cut, which translate to "all present
/// right" / "all present left".
#[test]
fn prop_threshold_translation_matches_float_traversal() {
    use xgb_tpu::predict::quantised::{threshold_to_bin, BinTree, QuantisedBatch};
    use xgb_tpu::tree::RegTree;
    check(0xb17bd, 30, |g: &mut Gen| {
        let n = g.int(20, 300);
        let cols = g.int(1, 5);
        // values on a coarse grid so many land exactly on cut values;
        // ~15% missing exercises the default direction
        let vals: Vec<Float> = (0..n * cols)
            .map(|_| {
                if g.bool(0.15) {
                    Float::NAN
                } else {
                    g.int(0, 12) as Float - 6.0
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, cols);
        let cuts = HistogramCuts::from_dmatrix(&x, g.int(2, 16), None);

        // grow a random tree whose thresholds are drawn from the cut
        // grid (the trained-tree invariant) plus the two edge classes
        let mut tree = RegTree::new_root(0.0, 1.0);
        let mut frontier = vec![(0usize, 0usize)];
        while let Some((nid, depth)) = frontier.pop() {
            if depth >= 4 || g.bool(0.3) {
                continue;
            }
            let f = g.int(0, cols - 1);
            let fc = cuts.feature_cuts(f);
            let threshold = match g.int(0, 9) {
                // below the first cut (and below every data value, so the
                // ambiguity-free "all present right" case)
                0 => -100.0,
                // above the sentinel: "all present left"
                1 => *fc.last().unwrap() + 100.0,
                _ => fc[g.int(0, fc.len() - 1)],
            };
            let (l, r) = tree.apply_split(
                nid,
                f as u32,
                threshold,
                g.bool(0.5),
                1.0,
                g.f32(-1.0, 1.0),
                1.0,
                g.f32(-1.0, 1.0),
                1.0,
            );
            frontier.push((l, depth + 1));
            frontier.push((r, depth + 1));
        }

        // the translation itself round-trips split bins exactly
        for f in 0..cols {
            for b in cuts.ptrs[f]..cuts.ptrs[f + 1] {
                assert_eq!(
                    threshold_to_bin(&cuts, f, cuts.cut_of_bin(b)),
                    b + 1,
                    "feature {f} bin {b}"
                );
            }
        }

        // and full traversal agrees with the float path on every row
        let bt = BinTree::from_tree(&tree, &cuts);
        let qb = QuantisedBatch::from_dmatrix(&x, &cuts, 0).unwrap();
        for r in 0..n {
            let float_leaf = tree.leaf_for_row(&x, r);
            let bin_leaf = bt.leaf_for(|f| qb.feature_bin(r, f));
            assert_eq!(float_leaf, bin_leaf, "row {r}");
        }
    });
}

/// Flattening preserves routing bit for bit: for random forests over
/// random cut grids, the serving-side `FlatForest` (shifted-bin SoA
/// arena, branchless traversal) returns exactly the leaf the `BinForest`
/// and the float traversal return — row for row, tree for tree,
/// including NaN/missing rows, values exactly on cut boundaries, and
/// thresholds below the first / above the sentinel cut — and the batch
/// margin accumulation matches the manual per-row tree-order sum at
/// every thread count.
#[test]
fn prop_flat_forest_matches_bin_and_float_traversal() {
    use xgb_tpu::predict::quantised::{BinForest, QuantisedBatch};
    use xgb_tpu::serve::FlatBatch;
    use xgb_tpu::tree::RegTree;
    check(0xf1a7, 30, |g: &mut Gen| {
        let n = g.int(20, 300);
        let cols = g.int(1, 5);
        // coarse value grid (many exact cut hits) + ~15% missing
        let vals: Vec<Float> = (0..n * cols)
            .map(|_| {
                if g.bool(0.15) {
                    Float::NAN
                } else {
                    g.int(0, 12) as Float - 6.0
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, cols);
        let cuts = HistogramCuts::from_dmatrix(&x, g.int(2, 16), None);

        // a small random forest whose thresholds are drawn from the cut
        // grid (the trained-tree invariant) plus the two edge classes
        let n_trees = g.int(1, 3);
        let mut trees: Vec<RegTree> = Vec::new();
        for _ in 0..n_trees {
            let mut tree = RegTree::new_root(0.0, 1.0);
            let mut frontier = vec![(0usize, 0usize)];
            while let Some((nid, depth)) = frontier.pop() {
                if depth >= 4 || g.bool(0.3) {
                    continue;
                }
                let f = g.int(0, cols - 1);
                let fc = cuts.feature_cuts(f);
                let threshold = match g.int(0, 9) {
                    0 => -100.0,
                    1 => *fc.last().unwrap() + 100.0,
                    _ => fc[g.int(0, fc.len() - 1)],
                };
                let (l, r) = tree.apply_split(
                    nid,
                    f as u32,
                    threshold,
                    g.bool(0.5),
                    1.0,
                    g.f32(-1.0, 1.0),
                    1.0,
                    g.f32(-1.0, 1.0),
                    1.0,
                );
                frontier.push((l, depth + 1));
                frontier.push((r, depth + 1));
            }
            trees.push(tree);
        }

        let bf = BinForest::from_trees(&[trees.clone()], &cuts);
        let flat = bf.flatten().unwrap();
        let qb = QuantisedBatch::from_dmatrix(&x, &cuts, 0).unwrap();
        let fb = FlatBatch::from_quantised(&qb, cols);
        let roots = flat.group_roots(0);
        assert_eq!(roots.len(), trees.len());
        for r in 0..n {
            for (t, (tree, bt)) in trees.iter().zip(&bf.groups[0]).enumerate() {
                let float_v = tree.nodes[tree.leaf_for_row(&x, r)].leaf_value;
                let bin_v = bt.leaf_value_for(|f| qb.feature_bin(r, f));
                let flat_v = flat.leaf_value(roots[t], |f| fb.bin(r, f as usize));
                assert_eq!(float_v.to_bits(), bin_v.to_bits(), "row {r} tree {t}: bin");
                assert_eq!(float_v.to_bits(), flat_v.to_bits(), "row {r} tree {t}: flat");
            }
        }

        // batch margins: same bracketing as the per-row manual sum
        let exec = xgb_tpu::exec::ExecContext::new(g.int(1, 3));
        let margins = flat.predict_margins(&[0.5], &fb, &exec);
        for r in 0..n {
            let mut want = 0.5 as Float;
            for bt in &bf.groups[0] {
                want += bt.leaf_value_for(|f| qb.feature_bin(r, f));
            }
            assert_eq!(margins[0][r].to_bits(), want.to_bits(), "row {r} margin");
        }
    });
}

/// The blocked, branchless histogram kernels (multi-symbol block unpack
/// + null-scratch-slot accumulation, `rust/src/hist` module docs) are
/// **bit-identical** to the scalar reference loops across symbol widths
/// {1, 5, 8, 9, 13}, dense and sparse-with-nulls layouts, row counts
/// straddling the `HIST_BLOCK_ROWS` and `ROW_CHUNK` boundaries, and
/// thread counts {1, 4} — and the packed builder stays bit-identical to
/// the unpacked one in both modes.
#[test]
fn prop_blocked_hist_matches_scalar_bitwise() {
    use xgb_tpu::exec::{ExecContext, KernelMode};
    use xgb_tpu::hist::{
        build_histogram_compressed_par_mode, build_histogram_quantized_par_mode, HistArena,
    };
    check(0xb10cd, 30, |g: &mut Gen| {
        // n_bins = 2^bits - 1 makes the packed alphabet (n_bins + 1
        // symbols incl. null) exactly `bits` wide
        let bits = [1usize, 5, 8, 9, 13][g.int(0, 4)];
        let n_bins = (1usize << bits) - 1;
        // straddle HIST_BLOCK_ROWS (8), BLOCK_ROWS (64) and ROW_CHUNK
        // (8192) boundaries
        let n_rows = [1usize, 7, 8, 9, 63, 64, 65, 200, 8193][g.int(0, 8)];
        let stride = g.int(1, 9);
        let dense = g.bool(0.5);
        let null_p = if dense { 0.0 } else { 0.3 };
        let bins: Vec<u32> = (0..n_rows * stride)
            .map(|_| {
                if g.bool(null_p) {
                    n_bins as u32 // null/padding symbol
                } else {
                    g.int(0, n_bins - 1) as u32
                }
            })
            .collect();
        let qm = xgb_tpu::quantile::QuantizedMatrix {
            bins,
            n_rows,
            n_features: stride,
            row_stride: stride,
            n_bins,
            dense,
        };
        let cm = CompressedMatrix::from_quantized(&qm);
        assert_eq!(cm.symbol_bits, bits as u32, "width selection");
        let grads = g.grad_pairs(n_rows);
        let rows: Vec<u32> = (0..n_rows as u32).collect();
        for threads in [1usize, 4] {
            let exec = ExecContext::new(threads);
            let arena = HistArena::default();
            let build_q = |mode| {
                let mut h = Histogram::zeros(n_bins);
                build_histogram_quantized_par_mode(&qm, &grads, &rows, &mut h, &exec, mode, &arena);
                h
            };
            let build_c = |mode| {
                let mut h = Histogram::zeros(n_bins);
                build_histogram_compressed_par_mode(&cm, &grads, &rows, &mut h, &exec, mode, &arena);
                h
            };
            let qs = build_q(KernelMode::Scalar);
            let qb = build_q(KernelMode::Blocked);
            let cs = build_c(KernelMode::Scalar);
            let cb = build_c(KernelMode::Blocked);
            for (kind, (s, b)) in [("quantized", (&qs, &qb)), ("compressed", (&cs, &cb))] {
                for (x, y) in s.bins.iter().zip(b.bins.iter()) {
                    assert_eq!(
                        x.grad.to_bits(),
                        y.grad.to_bits(),
                        "{kind} bits={bits} n={n_rows} stride={stride} threads={threads}"
                    );
                    assert_eq!(x.hess.to_bits(), y.hess.to_bits(), "{kind}");
                }
            }
            assert_eq!(qb, cb, "packed vs unpacked, blocked mode");
            assert_eq!(qs, cs, "packed vs unpacked, scalar mode");
        }
    });
}

/// The blocked, level-synchronous bin-tree traversal (default kernel
/// mode of `predict/quantised.rs`) routes every row to exactly the leaf
/// the row-at-a-time `BinTree` walk and the float traversal reach, and
/// accumulates margins bit-identically, over both the unpacked and the
/// bit-packed storages, at thread counts {1, 4} and row counts
/// straddling the `BLOCK_ROWS` boundary.
#[test]
fn prop_blocked_traversal_matches_rowwise_and_float() {
    use xgb_tpu::exec::ExecContext;
    use xgb_tpu::predict::quantised::{
        leaf_indices_compressed, predict_margins_compressed, predict_margins_quantized, BinForest,
    };
    use xgb_tpu::tree::RegTree;
    check(0xb70c7, 20, |g: &mut Gen| {
        let n = [1usize, 63, 64, 65, 130, 300][g.int(0, 5)];
        let cols = g.int(1, 5);
        // coarse value grid (many exact cut hits) + ~15% missing
        let vals: Vec<Float> = (0..n * cols)
            .map(|_| {
                if g.bool(0.15) {
                    Float::NAN
                } else {
                    g.int(0, 12) as Float - 6.0
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, cols);
        let cuts = HistogramCuts::from_dmatrix(&x, g.int(2, 16), None);

        // random forest whose thresholds are cut values (the trained-
        // tree invariant)
        let n_trees = g.int(1, 3);
        let mut trees: Vec<RegTree> = Vec::new();
        for _ in 0..n_trees {
            let mut tree = RegTree::new_root(g.f32(-0.5, 0.5), 1.0);
            let mut frontier = vec![(0usize, 0usize)];
            while let Some((nid, depth)) = frontier.pop() {
                if depth >= 4 || g.bool(0.3) {
                    continue;
                }
                let f = g.int(0, cols - 1);
                let fc = cuts.feature_cuts(f);
                let threshold = fc[g.int(0, fc.len() - 1)];
                let (l, r) = tree.apply_split(
                    nid,
                    f as u32,
                    threshold,
                    g.bool(0.5),
                    1.0,
                    g.f32(-1.0, 1.0),
                    1.0,
                    g.f32(-1.0, 1.0),
                    1.0,
                );
                frontier.push((l, depth + 1));
                frontier.push((r, depth + 1));
            }
            trees.push(tree);
        }

        let bf = BinForest::from_trees(&[trees.clone()], &cuts);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let cm = CompressedMatrix::from_quantized(&qm);
        let base = [0.25 as Float];
        let float = xgb_tpu::predict::predict_margins(&[trees.clone()], &base, &x);
        for threads in [1usize, 4] {
            let exec = ExecContext::new(threads);
            let mq = predict_margins_quantized(&bf, &base, &qm, &cuts, &exec);
            let mc = predict_margins_compressed(&bf, &base, &cm, &cuts, &exec);
            let li = leaf_indices_compressed(&bf.groups[0], &cm, &cuts, &exec);
            for r in 0..n {
                // row-at-a-time reference walk over the same bins
                let mut want = base[0];
                for bt in &bf.groups[0] {
                    want += bt.leaf_value_for(|f| qm.get(r, f));
                }
                assert_eq!(
                    mq[0][r].to_bits(),
                    want.to_bits(),
                    "row {r} threads={threads}: blocked vs row-wise (quantized)"
                );
                assert_eq!(
                    mc[0][r].to_bits(),
                    want.to_bits(),
                    "row {r} threads={threads}: blocked vs row-wise (compressed)"
                );
                assert_eq!(
                    float[0][r].to_bits(),
                    mq[0][r].to_bits(),
                    "row {r} threads={threads}: blocked vs float"
                );
                for (t, bt) in bf.groups[0].iter().enumerate() {
                    assert_eq!(
                        li[t][r] as usize,
                        bt.leaf_for(|f| qm.get(r, f)),
                        "row {r} tree {t}: blocked leaf index"
                    );
                }
            }
        }
    });
}

/// The persistent parked-pool engine is **bit-identical** to the scoped
/// spawn-per-call reference engine across the full training pipeline:
/// same trees, base score, eval history and predictions at thread counts
/// {1, 2, 4, 8}, with multi-device shards (nested `ExecContext::fork`
/// budget sub-slices over the one shared pool), on the fully resident,
/// spilled-page and streamed-ingest data paths. Both engines share the
/// fixed-chunk split and ascending-index merge by construction; this
/// pins the contract end to end.
#[test]
fn prop_persistent_pool_matches_scoped_engine() {
    use xgb_tpu::data::source::DMatrixSource;
    use xgb_tpu::data::synthetic::{generate, DatasetSpec};
    use xgb_tpu::exec::{set_exec_mode_override, ExecMode};
    use xgb_tpu::gbm::{Learner, LearnerParams, ObjectiveKind};

    check(0xec5d, 2, |g: &mut Gen| {
        let ds = generate(
            &DatasetSpec::higgs_like(g.int(150, 350)),
            g.int(1, 1000) as u64,
        );
        // 3 devices ⇒ the coordinator forks the pool into per-shard
        // budget sub-slices (nested parallelism, no extra threads)
        let devices = [1usize, 3][g.int(0, 1)];
        for threads in [1usize, 2, 4, 8] {
            let params = LearnerParams {
                objective: ObjectiveKind::BinaryLogistic,
                num_rounds: 3,
                max_depth: 3,
                max_bins: 16,
                n_devices: devices,
                threads,
                compress: true,
                eval_every: 1,
                ..Default::default()
            };
            let mut paged = params.clone();
            paged.max_resident_pages = 2;
            paged.page_rows = 64;
            let run = |p: &LearnerParams, mode: ExecMode, streamed: bool| {
                set_exec_mode_override(Some(mode));
                let booster = if streamed {
                    let mut src = DMatrixSource::from_dataset(&ds.train, 96);
                    Learner::from_params(p.clone())
                        .unwrap()
                        .train_from_source(&mut src, Some(&ds.valid))
                        .unwrap()
                } else {
                    Learner::from_params(p.clone())
                        .unwrap()
                        .train(&ds.train, Some(&ds.valid))
                        .unwrap()
                };
                set_exec_mode_override(None);
                booster
            };
            for (name, p, streamed) in [
                ("resident", &params, false),
                ("paged", &paged, false),
                ("streamed", &params, true),
            ] {
                let scoped = run(p, ExecMode::Scoped, streamed);
                let pooled = run(p, ExecMode::Persistent, streamed);
                let ctx = format!("{name} devices={devices} threads={threads}");
                assert_eq!(scoped.trees, pooled.trees, "{ctx}: trees");
                assert_eq!(scoped.base_score, pooled.base_score, "{ctx}: base score");
                assert_eq!(
                    scoped.eval_history.len(),
                    pooled.eval_history.len(),
                    "{ctx}: eval history length"
                );
                for (a, b) in scoped.eval_history.iter().zip(pooled.eval_history.iter()) {
                    assert_eq!(
                        a.train.to_bits(),
                        b.train.to_bits(),
                        "{ctx} round {}: train metric",
                        a.round
                    );
                    assert_eq!(
                        a.valid.map(f64::to_bits),
                        b.valid.map(f64::to_bits),
                        "{ctx} round {}: valid metric",
                        a.round
                    );
                }
                assert_eq!(
                    scoped.predict(&ds.valid.x),
                    pooled.predict(&ds.valid.x),
                    "{ctx}: predictions"
                );
            }
        }
    });
}

/// Quantised histogram totals equal direct gradient sums per feature.
#[test]
fn prop_histogram_mass_conservation() {
    check(0xb157, 40, |g: &mut Gen| {
        let n = g.int(10, 300);
        let cols = g.int(1, 4);
        let vals: Vec<Float> = (0..n * cols)
            .map(|_| if g.bool(0.2) { Float::NAN } else { g.f32(0.0, 1.0) })
            .collect();
        let x = DMatrix::dense(vals, n, cols);
        let grads = g.grad_pairs(n);
        let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hist);
        for f in 0..cols {
            let lo = cuts.ptrs[f] as usize;
            let hi = cuts.ptrs[f + 1] as usize;
            let feat_sum = hist.feature_sum(lo, hi);
            let mut expect = GradPairF64::default();
            x.for_each_in_column(f, |r, _| {
                expect += GradPairF64::from_single(grads[r]);
            });
            assert!((feat_sum.grad - expect.grad).abs() < 1e-6, "feature {f}");
            assert!((feat_sum.hess - expect.hess).abs() < 1e-6, "feature {f}");
        }
    });
}

/// Finite-difference check over **every** registered objective: the
/// analytic gradient matches the central difference of the reference loss,
/// and the hessian matches the FD second derivative — except where the
/// implementation documents a different convention (quantile's constant
/// unit hessian, softmax's `2p(1−p)`), which is pinned analytically
/// instead. The scenario references are the same `pub` loss helpers the
/// gradient code differentiates (`pinball_loss`, `tweedie_nll`, `aft_nll`),
/// so a sign or scale bug cannot hide in a private copy. A trailing
/// coverage assertion fails when a new objective registers without an FD
/// block here.
#[test]
fn prop_objective_gradients_match_finite_difference() {
    use xgb_tpu::data::Dataset;
    use xgb_tpu::gbm::objective::{aft_nll, pinball_loss, tweedie_nll};
    use xgb_tpu::gbm::{
        AftDistribution, Objective, ObjectiveKind, ObjectiveParams, ObjectiveRegistry,
    };

    const EPS_G: f64 = 1e-5; // central-difference step for gradients
    const EPS_H: f64 = 1e-4; // wider step for second differences

    // FD first and second derivative of `loss` at `m`
    let fd = |loss: &dyn Fn(f64) -> f64, m: f64| -> (f64, f64) {
        let g = (loss(m + EPS_G) - loss(m - EPS_G)) / (2.0 * EPS_G);
        let h = (loss(m + EPS_H) - 2.0 * loss(m) + loss(m - EPS_H)) / (EPS_H * EPS_H);
        (g, h)
    };
    let close = |fd_val: f64, got: Float, rtol: f64| -> bool {
        (fd_val - got as f64).abs() <= rtol * fd_val.abs().max(1.0)
    };
    let dense0 = |n: usize| DMatrix::dense(vec![0.0; n], n, 1);

    check(0xfd0b7, 20, |g: &mut Gen| {
        let n = g.int(8, 24);
        let op = ObjectiveParams {
            num_class: g.int(2, 4),
            quantile_alpha: g.f64(0.05, 0.95),
            tweedie_variance_power: g.f64(1.1, 1.9),
            aft_distribution: if g.bool(0.5) {
                AftDistribution::Normal
            } else {
                AftDistribution::Logistic
            },
            aft_sigma: g.f64(0.5, 1.5),
        };
        let mut covered: Vec<&str> = Vec::new();

        // reg:squarederror — L = ½(m − y)²
        {
            let y: Vec<Float> = (0..n).map(|_| g.f32(-5.0, 5.0)).collect();
            let m: Vec<Float> = (0..n).map(|_| g.f32(-5.0, 5.0)).collect();
            let ds = Dataset::new(dense0(n), y.clone());
            let obj = ObjectiveRegistry::create_with("reg:squarederror", &op).unwrap();
            let gr = obj.gradients(&ds, &[m.clone()]);
            for i in 0..n {
                let yi = y[i] as f64;
                let loss = move |mm: f64| 0.5 * (mm - yi) * (mm - yi);
                let (fg, fh) = fd(&loss, m[i] as f64);
                assert!(close(fg, gr[0][i].grad, 1e-3), "sqerr grad {i}: {fg} vs {}", gr[0][i].grad);
                assert!(close(fh, gr[0][i].hess, 1e-2), "sqerr hess {i}: {fh} vs {}", gr[0][i].hess);
            }
            covered.push("reg:squarederror");
        }

        // binary:logistic — L = ln(1 + e^m) − y·m (cross-entropy)
        {
            let y: Vec<Float> = (0..n).map(|_| g.bool(0.5) as u32 as Float).collect();
            let m: Vec<Float> = (0..n).map(|_| g.f32(-3.0, 3.0)).collect();
            let ds = Dataset::new(dense0(n), y.clone());
            let obj = ObjectiveRegistry::create_with("binary:logistic", &op).unwrap();
            let gr = obj.gradients(&ds, &[m.clone()]);
            for i in 0..n {
                let yi = y[i] as f64;
                let loss = move |mm: f64| (1.0 + mm.exp()).ln() - yi * mm;
                let (fg, fh) = fd(&loss, m[i] as f64);
                assert!(close(fg, gr[0][i].grad, 1e-3), "logistic grad {i}");
                assert!(close(fh, gr[0][i].hess, 1e-2), "logistic hess {i}");
            }
            covered.push("binary:logistic");
        }

        // multi:softmax / multi:softprob — L_i = ln Σ_j e^{m_j} − m_label;
        // FD checks the gradient; the hessian is XGBoost's 2p(1−p)
        // convention (not the CE second derivative p(1−p)), pinned
        // analytically. softprob shares the gradient code bit for bit.
        {
            let k = op.num_class;
            let y: Vec<Float> = (0..n).map(|_| g.int(0, k - 1) as Float).collect();
            let m: Vec<Vec<Float>> = (0..k)
                .map(|_| (0..n).map(|_| g.f32(-2.0, 2.0)).collect())
                .collect();
            let ds = Dataset::new(dense0(n), y.clone());
            let obj = ObjectiveRegistry::create_with("multi:softmax", &op).unwrap();
            let gr = obj.gradients(&ds, &m);
            for i in 0..n {
                let label = y[i] as usize;
                let base: Vec<f64> = (0..k).map(|c| m[c][i] as f64).collect();
                for c in 0..k {
                    let b = base.clone();
                    let loss = move |mm: f64| {
                        let mut v = b.clone();
                        v[c] = mm;
                        let mx = v.iter().cloned().fold(f64::MIN, f64::max);
                        let lse = mx + v.iter().map(|&x| (x - mx).exp()).sum::<f64>().ln();
                        lse - v[label]
                    };
                    let (fg, _) = fd(&loss, base[c]);
                    assert!(close(fg, gr[c][i].grad, 1e-3), "softmax grad row {i} class {c}");
                    let mx = base.iter().cloned().fold(f64::MIN, f64::max);
                    let z: f64 = base.iter().map(|&x| (x - mx).exp()).sum();
                    let p = (base[c] - mx).exp() / z;
                    let want_h = (2.0 * p * (1.0 - p)).max(1e-16);
                    assert!(
                        (want_h - gr[c][i].hess as f64).abs() <= 1e-4 * want_h.max(1.0),
                        "softmax hess row {i} class {c}: 2p(1−p) = {want_h} vs {}",
                        gr[c][i].hess
                    );
                }
            }
            let prob = ObjectiveRegistry::create_with("multi:softprob", &op).unwrap();
            assert_eq!(prob.gradients(&ds, &m), gr, "softprob shares softmax gradients");
            covered.push("multi:softmax");
            covered.push("multi:softprob");
        }

        // rank:pairwise — L = Σ_{groups} Σ_{y_i > y_j} ln(1 + e^{−(s_i − s_j)});
        // the FD second derivative also matches because the implementation's
        // hessian is the true ρ(1−ρ) pair sum (the 1e-16 base seed is far
        // below the tolerance).
        {
            let mut groups = vec![0usize];
            let mut nn = 0usize;
            for _ in 0..3 {
                nn += g.int(2, 6);
                groups.push(nn);
            }
            let y: Vec<Float> = (0..nn).map(|_| g.int(0, 3) as Float).collect();
            let m: Vec<Float> = (0..nn).map(|_| g.f32(-2.0, 2.0)).collect();
            let ds = Dataset::with_groups(dense0(nn), y.clone(), groups.clone());
            let obj = ObjectiveRegistry::create_with("rank:pairwise", &op).unwrap();
            let gr = obj.gradients(&ds, &[m.clone()]);
            let base: Vec<f64> = m.iter().map(|&v| v as f64).collect();
            let total = |mv: &[f64]| -> f64 {
                let mut l = 0.0;
                for w in groups.windows(2) {
                    for i in w[0]..w[1] {
                        for j in w[0]..w[1] {
                            if y[i] > y[j] {
                                l += (1.0 + (-(mv[i] - mv[j])).exp()).ln();
                            }
                        }
                    }
                }
                l
            };
            for i in 0..nn {
                let b = base.clone();
                let loss = move |mm: f64| {
                    let mut v = b.clone();
                    v[i] = mm;
                    total(&v)
                };
                let (fg, fh) = fd(&loss, base[i]);
                assert!(close(fg, gr[0][i].grad, 1e-3), "pairwise grad {i}");
                assert!(close(fh, gr[0][i].hess, 1e-2), "pairwise hess {i}");
            }
            covered.push("rank:pairwise");
        }

        // reg:quantile — piecewise-linear pinball loss: FD validates the
        // gradient away from the kink; at and around it the documented
        // subgradient convention and the constant unit hessian are pinned.
        {
            let alpha = op.quantile_alpha;
            let y: Vec<Float> = (0..n).map(|_| g.f32(-5.0, 5.0)).collect();
            let m: Vec<Float> = (0..n).map(|_| g.f32(-5.0, 5.0)).collect();
            let ds = Dataset::new(dense0(n), y.clone());
            let obj = ObjectiveRegistry::create_with("reg:quantile", &op).unwrap();
            let gr = obj.gradients(&ds, &[m.clone()]);
            for i in 0..n {
                let (yi, mi) = (y[i] as f64, m[i] as f64);
                let want = if yi - mi > 0.0 { -alpha } else { 1.0 - alpha };
                assert!(
                    (gr[0][i].grad as f64 - want).abs() < 1e-6,
                    "quantile subgradient convention row {i}"
                );
                assert_eq!(gr[0][i].hess, 1.0, "quantile hessian is the unit constant");
                if (yi - mi).abs() > 4.0 * EPS_G {
                    let loss = move |mm: f64| pinball_loss(alpha, yi, mm);
                    let (fg, _) = fd(&loss, mi);
                    assert!(close(fg, gr[0][i].grad, 1e-3), "quantile FD grad {i}");
                }
            }
            covered.push("reg:quantile");
        }

        // reg:tweedie — L = tweedie_nll; moderate margins keep the hessian
        // floor inactive so FD checks both derivatives (zero labels
        // included: the (2−ρ) term keeps h strictly positive).
        {
            let rho = op.tweedie_variance_power;
            let y: Vec<Float> = (0..n)
                .map(|_| if g.bool(0.2) { 0.0 } else { g.f32(0.1, 8.0) })
                .collect();
            let m: Vec<Float> = (0..n).map(|_| g.f32(-1.5, 1.5)).collect();
            let ds = Dataset::new(dense0(n), y.clone());
            let obj = ObjectiveRegistry::create_with("reg:tweedie", &op).unwrap();
            let gr = obj.gradients(&ds, &[m.clone()]);
            for i in 0..n {
                let yi = y[i] as f64;
                let loss = move |mm: f64| tweedie_nll(rho, yi, mm);
                let (fg, fh) = fd(&loss, m[i] as f64);
                assert!(close(fg, gr[0][i].grad, 1e-3), "tweedie grad {i}");
                assert!(close(fh, gr[0][i].hess, 1e-2), "tweedie hess {i}");
            }
            covered.push("reg:tweedie");
        }

        // survival:aft — L = aft_nll over all four censoring shapes;
        // margins stay near ln t so the likelihood clamps are inactive and
        // FD checks both derivatives.
        {
            let (dist, sigma) = (op.aft_distribution, op.aft_sigma);
            let mut lo = Vec::with_capacity(n);
            let mut up = Vec::with_capacity(n);
            let mut m: Vec<Float> = Vec::with_capacity(n);
            for _ in 0..n {
                let t = g.f64(1.0, 10.0) as Float;
                let (l, u) = match g.int(0, 3) {
                    0 => (t, t),                           // uncensored
                    1 => (t, Float::INFINITY),             // right-censored
                    2 => (0.0, t),                         // left-censored
                    _ => (t, t + g.f64(1.0, 5.0) as Float), // interval
                };
                lo.push(l);
                up.push(u);
                m.push((t as f64).ln() as Float + g.f32(-1.0, 1.0));
            }
            let ds = Dataset::with_bounds(dense0(n), lo.clone(), up.clone());
            let obj = ObjectiveRegistry::create_with("survival:aft", &op).unwrap();
            let gr = obj.gradients(&ds, &[m.clone()]);
            for i in 0..n {
                let (li, ui) = (lo[i] as f64, up[i] as f64);
                let loss = move |mm: f64| aft_nll(dist, sigma, li, ui, mm);
                let (fg, fh) = fd(&loss, m[i] as f64);
                assert!(
                    close(fg, gr[0][i].grad, 2e-3),
                    "aft {dist:?} grad {i}: {fg} vs {}",
                    gr[0][i].grad
                );
                assert!(
                    close(fh, gr[0][i].hess, 2e-2),
                    "aft {dist:?} hess {i}: {fh} vs {}",
                    gr[0][i].hess
                );
            }
            covered.push("survival:aft");
        }

        let mut want: Vec<&str> = ObjectiveKind::BUILTIN_NAMES.to_vec();
        want.sort_unstable();
        covered.sort_unstable();
        assert_eq!(covered, want, "every registered objective must be FD-checked");
    });
}

/// Hessian-floor parity pin: with saturating margins the Softmax and
/// PairwiseRank hessian floors engage, and the chunk-parallel
/// `gradients_par_into` reproduces the floored values **bit for bit** at
/// every thread count. The serial and parallel paths share the per-row /
/// per-group helpers; this pins that they stay shared (a floor applied in
/// only one of the two would desynchronise resident vs pooled training).
#[test]
fn prop_hessian_floor_parity_serial_vs_parallel() {
    use xgb_tpu::data::Dataset;
    use xgb_tpu::exec::ExecContext;
    use xgb_tpu::gbm::{Objective, ObjectiveParams, ObjectiveRegistry};
    check(0xf10c4, 6, |g: &mut Gen| {
        let n = 20_000 + g.int(0, 4000); // > ROW_CHUNK so chunking engages
        let op = ObjectiveParams {
            num_class: 3,
            ..Default::default()
        };

        // softmax: one dominant class per row drives p → {0, 1} and the
        // 2p(1−p) hessian to exact 0, caught by the 1e-16 floor
        let y: Vec<Float> = (0..n).map(|_| g.int(0, 2) as Float).collect();
        let margins: Vec<Vec<Float>> = {
            let winner: Vec<usize> = (0..n).map(|_| g.int(0, 2)).collect();
            let saturated: Vec<bool> = (0..n).map(|_| g.bool(0.5)).collect();
            (0..3)
                .map(|c| {
                    (0..n)
                        .map(|i| {
                            if !saturated[i] {
                                g.f32(-2.0, 2.0)
                            } else if winner[i] == c {
                                40.0
                            } else {
                                -40.0
                            }
                        })
                        .collect()
                })
                .collect()
        };
        let ds = Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y);
        let soft = ObjectiveRegistry::create_with("multi:softmax", &op).unwrap();
        let serial = soft.gradients(&ds, &margins);
        let floored = serial
            .iter()
            .flat_map(|class| class.iter())
            .filter(|p| p.hess == 1e-16)
            .count();
        assert!(floored > 0, "saturated rows must hit the softmax hessian floor");
        for t in [2usize, 8] {
            let par = soft.gradients_par(&ds, &margins, &ExecContext::new(t));
            assert_eq!(par, serial, "softmax floor parity, threads = {t}");
        }

        // pairwise: pairs separated by ±40 margins drive ρ(1−ρ) below the
        // per-pair floor; the chunked group path must reproduce the floored
        // accumulation exactly
        let mut groups = vec![0usize];
        let mut nn = 0usize;
        while nn < 20_000 {
            nn += 2 + g.int(0, 4);
            groups.push(nn);
        }
        let yr: Vec<Float> = (0..nn).map(|_| g.int(0, 2) as Float).collect();
        let mr: Vec<Float> = (0..nn)
            .map(|_| if g.bool(0.3) { 40.0 * if g.bool(0.5) { 1.0 } else { -1.0 } } else { g.f32(-2.0, 2.0) })
            .collect();
        let dsr = Dataset::with_groups(DMatrix::dense(vec![0.0; nn], nn, 1), yr, groups);
        let rank = ObjectiveRegistry::create_with("rank:pairwise", &op).unwrap();
        let rs = rank.gradients(&dsr, &[mr.clone()]);
        assert!(rs[0].iter().all(|p| p.hess >= 1e-16), "pairwise hessians keep the floor");
        for t in [2usize, 8] {
            let par = rank.gradients_par(&dsr, &[mr.clone()], &ExecContext::new(t));
            assert_eq!(par, rs, "pairwise floor parity, threads = {t}");
        }
    });
}

/// Unknown objective names error with the complete registered-name list —
/// the CLI surfaces this message verbatim, so the scenario objectives must
/// all appear in it.
#[test]
fn unknown_objective_error_lists_every_registered_name() {
    use xgb_tpu::gbm::{ObjectiveKind, ObjectiveRegistry};
    let err = ObjectiveRegistry::create("not-an-objective", 1).unwrap_err();
    let msg = format!("{err:#}");
    for name in ObjectiveKind::BUILTIN_NAMES {
        assert!(msg.contains(name), "error must list {name}: {msg}");
    }
}
