//! Serving-stack parity suite: responses streamed through the full
//! `serve` path (parse → micro-batch queue → FlatForest → reply writer)
//! must be **bit-identical** to `Booster::predict` on the same rows —
//! across {dense with missing, sparse with stored NaN + col base,
//! multiclass softprob} × threads {1,4} × batch_max {1,7,64} — with
//! responses in request order, the stream checksum equal to the
//! `predict` CLI's FNV-1a fingerprint, and correctness preserved across
//! a mid-stream atomic hot-swap (old rows on the old epoch, new rows on
//! the new one) including swaps racing in-flight concurrent streams.

use std::path::PathBuf;
use std::sync::Arc;

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::{DMatrix, Dataset};
use xgb_tpu::gbm::{Booster, Learner, LearnerParams};
use xgb_tpu::predict::prediction_checksum;
use xgb_tpu::serve::{ModelRegistry, ServeOptions, Server};
use xgb_tpu::Float;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("xgb_tpu_serving_{name}_{}.txt", std::process::id()))
}

fn train(objective: &str, num_class: usize, rounds: usize, seed: u64, rows: usize) -> (Booster, Dataset) {
    let spec = if num_class > 1 {
        DatasetSpec::covtype_like(rows)
    } else {
        DatasetSpec::higgs_like(rows)
    };
    let g = generate(&spec, seed);
    let params = LearnerParams {
        objective: objective.parse().expect("known objective"),
        num_class,
        num_rounds: rounds,
        max_depth: 3,
        max_bins: 16,
        eval_every: 0,
        ..Default::default()
    };
    let booster = Learner::from_params(params).unwrap().train(&g.train, None).unwrap();
    (booster, g.valid)
}

/// Run one in-memory stream through a server and return its output
/// lines + summary.
fn run_stream(server: &Server, input: &str) -> (Vec<String>, xgb_tpu::serve::StreamSummary) {
    let mut out: Vec<u8> = Vec::new();
    let summary = server.serve_stream(input.as_bytes(), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    (text.lines().map(|l| l.to_string()).collect(), summary)
}

/// Parse one response line into floats and compare bitwise against the
/// expected slice (Display round-trips f32 exactly, so equality of the
/// parsed bits is equality of the served bits).
fn assert_line_matches(line: &str, want: &[Float], ctx: &str) {
    let got: Vec<Float> = line
        .split_whitespace()
        .map(|t| t.parse::<Float>().unwrap())
        .collect();
    assert_eq!(got.len(), want.len(), "{ctx}: output arity; line {line:?}");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: value {i}: {g} vs {w}");
    }
}

/// One parity case: request lines + the float matrix `predict` sees.
struct Case {
    name: &'static str,
    booster: Booster,
    requests: Vec<String>,
    expected: Vec<Float>,
    outputs_per_row: usize,
    col_base: u32,
}

/// Dense requests from the valid matrix, with every third row's second
/// feature blanked (empty token = missing, DMatrix semantics).
fn dense_case(name: &'static str, objective: &str, num_class: usize, seed: u64) -> Case {
    let (booster, valid) = train(objective, num_class, 3, seed, 400);
    let n = valid.x.n_rows();
    let cols = valid.x.n_cols();
    let mut vals: Vec<Float> = Vec::with_capacity(n * cols);
    let mut requests = Vec::with_capacity(n);
    for r in 0..n {
        let mut toks: Vec<String> = Vec::with_capacity(cols);
        for c in 0..cols {
            let v = valid.x.get(r, c).unwrap_or(Float::NAN);
            if c == 1 && r % 3 == 0 {
                vals.push(Float::NAN);
                toks.push(String::new());
            } else {
                vals.push(v);
                toks.push(format!("{v}"));
            }
        }
        requests.push(toks.join(","));
    }
    let x = DMatrix::dense(vals, n, cols);
    let expected = booster.predict(&x);
    let outputs_per_row = expected.len() / n;
    Case {
        name,
        booster,
        requests,
        expected,
        outputs_per_row,
        col_base: 0,
    }
}

/// Sparse LibSVM-style requests (1-based indices, `--col-base 1`): every
/// fifth row omits feature 0 (missing), every seventh carries an
/// explicit `nan` value on feature 2 (a STORED NaN — present, routes
/// right at every split, unlike an absent slot's default direction).
fn sparse_case(seed: u64) -> Case {
    let (booster, valid) = train("binary:logistic", 1, 3, seed, 400);
    let n = valid.x.n_rows();
    let cols = valid.x.n_cols();
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<Float> = Vec::new();
    let mut requests = Vec::with_capacity(n);
    for r in 0..n {
        let mut toks: Vec<String> = Vec::new();
        for c in 0..cols {
            if c == 0 && r % 5 == 0 {
                continue; // absent entry: default-direction routing
            }
            let v = if c == 2 && r % 7 == 0 {
                Float::NAN // stored NaN
            } else {
                valid.x.get(r, c).unwrap_or(Float::NAN)
            };
            indices.push(c as u32);
            values.push(v);
            toks.push(if v.is_nan() {
                format!("{}:nan", c + 1)
            } else {
                format!("{}:{v}", c + 1)
            });
        }
        indptr.push(indices.len());
        requests.push(toks.join(" "));
    }
    let x = DMatrix::csr(indptr, indices, values, n, cols);
    let expected = booster.predict(&x);
    Case {
        name: "sparse-storednan",
        booster,
        requests,
        expected,
        outputs_per_row: 1,
        col_base: 1,
    }
}

/// The tentpole acceptance sweep: every case × threads × batch_max
/// serves bit-identically to `predict`, in order, with the `predict`
/// CLI's exact checksum.
#[test]
fn served_responses_bit_match_predict_across_threads_and_batching() {
    let cases = [
        dense_case("dense-binary", "binary:logistic", 1, 11),
        sparse_case(12),
        dense_case("multiclass-softprob", "multi:softprob", 7, 13),
    ];
    for case in &cases {
        let path = tmp(case.name);
        xgb_tpu::gbm::save_model_file(&case.booster, &path).unwrap();
        let n = case.requests.len();
        let k = case.outputs_per_row;
        let input: String = case.requests.iter().map(|r| format!("{r}\n")).collect();
        for threads in [1usize, 4] {
            for batch_max in [1usize, 7, 64] {
                let ctx = format!("{} t={threads} b={batch_max}", case.name);
                let registry = Arc::new(ModelRegistry::open(&path).unwrap());
                let opts = ServeOptions {
                    batch_max,
                    threads,
                    col_base: case.col_base,
                    ..Default::default()
                };
                let server = Server::start(registry, opts, None);
                let (lines, summary) = run_stream(&server, &input);
                assert_eq!(lines.len(), n, "{ctx}: one response per request");
                for (r, line) in lines.iter().enumerate() {
                    assert_line_matches(line, &case.expected[r * k..(r + 1) * k], &format!("{ctx} row {r}"));
                }
                assert_eq!(summary.served, n as u64, "{ctx}");
                assert_eq!(summary.errors, 0, "{ctx}");
                assert_eq!(summary.n_values, (n * k) as u64, "{ctx}");
                assert_eq!(
                    summary.checksum,
                    prediction_checksum(&case.expected),
                    "{ctx}: stream fingerprint == predict CLI checksum"
                );
                assert_eq!(
                    summary.prediction_line(),
                    format!(
                        "predictions: n={} checksum={:#018x}",
                        n * k,
                        prediction_checksum(&case.expected)
                    ),
                    "{ctx}: the shutdown line byte-matches predict's"
                );
                let stats = server.shutdown();
                assert_eq!(stats.requests, n as u64, "{ctx}");
                assert!(stats.batches > 0 && stats.batches <= n as u64, "{ctx}");
                if batch_max == 1 {
                    assert_eq!(stats.batches, n as u64, "{ctx}: unit batches");
                }
                assert!(stats.p50_us > 0 && stats.p99_us >= stats.p50_us, "{ctx}: non-trivial latency stats");
                assert!(!stats.batch_sizes.is_empty(), "{ctx}");
            }
        }
        std::fs::remove_file(&path).ok();
    }
}

/// Mid-stream `!reload`: rows before the verb are scored by the model
/// loaded at open (epoch 1), the verb answers in stream position with
/// the new epoch, rows after are scored by the rewritten file (epoch 2),
/// and the stream checksum fingerprints exactly that A-then-B sequence.
#[test]
fn mid_stream_hot_swap_serves_old_then_new_epoch() {
    let (a, valid) = train("binary:logistic", 1, 2, 21, 400);
    let (b, _) = train("binary:logistic", 1, 4, 22, 400);
    let path = tmp("hotswap");
    xgb_tpu::gbm::save_model_file(&a, &path).unwrap();
    let registry = Arc::new(ModelRegistry::open(&path).unwrap());
    let server = Server::start(registry, ServeOptions::default(), None);
    // epoch 1 is in memory; the file on disk now carries model B
    xgb_tpu::gbm::save_model_file(&b, &path).unwrap();

    let n = valid.x.n_rows();
    let cols = valid.x.n_cols();
    let row_line = |r: usize| -> String {
        (0..cols)
            .map(|c| format!("{}", valid.x.get(r, c).unwrap_or(Float::NAN)))
            .collect::<Vec<_>>()
            .join(",")
    };
    let split = n / 2;
    let mut input = String::new();
    for r in 0..split {
        input.push_str(&row_line(r));
        input.push('\n');
    }
    input.push_str("!reload\n");
    for r in split..n {
        input.push_str(&row_line(r));
        input.push('\n');
    }

    let want_a = a.predict(&valid.x);
    let want_b = b.predict(&valid.x);
    let (lines, summary) = run_stream(&server, &input);
    assert_eq!(lines.len(), n + 1, "rows + the reload ack");
    for r in 0..split {
        assert_line_matches(&lines[r], &want_a[r..=r], &format!("pre-swap row {r}"));
    }
    assert_eq!(lines[split], "!ok epoch=2 swaps=1", "reload ack in stream position");
    for r in split..n {
        assert_line_matches(&lines[r + 1], &want_b[r..=r], &format!("post-swap row {r}"));
    }
    // fingerprint covers exactly the A-prefix then B-suffix values
    let mut seq: Vec<Float> = want_a[..split].to_vec();
    seq.extend_from_slice(&want_b[split..]);
    assert_eq!(summary.checksum, prediction_checksum(&seq));
    let stats = server.shutdown();
    assert_eq!(stats.swaps, 1);
    std::fs::remove_file(&path).ok();
}

/// Hot-swap racing in-flight load: two concurrent streams hammer the
/// queue while a third thread swaps the model file. Epoch atomicity
/// means every response must equal model A's or model B's prediction
/// for its row — never a mixture — and each stream still answers in
/// its own request order.
#[test]
fn concurrent_streams_survive_hot_swap_with_per_row_epoch_atomicity() {
    let (a, valid) = train("binary:logistic", 1, 2, 31, 400);
    let (b, _) = train("binary:logistic", 1, 5, 32, 400);
    let path = tmp("race");
    xgb_tpu::gbm::save_model_file(&a, &path).unwrap();
    let registry = Arc::new(ModelRegistry::open(&path).unwrap());
    let opts = ServeOptions {
        batch_max: 8,
        threads: 2,
        ..Default::default()
    };
    let server = Server::start(registry, opts, None);
    xgb_tpu::gbm::save_model_file(&b, &path).unwrap();

    let n = valid.x.n_rows();
    let cols = valid.x.n_cols();
    let input: String = (0..n)
        .map(|r| {
            let toks: Vec<String> = (0..cols)
                .map(|c| format!("{}", valid.x.get(r, c).unwrap_or(Float::NAN)))
                .collect();
            format!("{}\n", toks.join(","))
        })
        .collect();
    let want_a = a.predict(&valid.x);
    let want_b = b.predict(&valid.x);

    std::thread::scope(|scope| {
        let streams: Vec<_> = (0..2)
            .map(|_| {
                let server = &server;
                let input = &input;
                scope.spawn(move || run_stream(server, input))
            })
            .collect();
        // swap while both streams are in flight
        let swapper = scope.spawn(|| server.registry().reload().unwrap());
        let epoch = swapper.join().unwrap();
        assert_eq!(epoch, 2);
        for handle in streams {
            let (lines, summary) = handle.join().unwrap();
            assert_eq!(lines.len(), n);
            assert_eq!(summary.served, n as u64);
            assert_eq!(summary.errors, 0);
            for (r, line) in lines.iter().enumerate() {
                let got: Float = line.parse().unwrap();
                assert!(
                    got.to_bits() == want_a[r].to_bits() || got.to_bits() == want_b[r].to_bits(),
                    "row {r}: {got} is neither epoch's prediction"
                );
            }
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.swaps, 1);
    std::fs::remove_file(&path).ok();
}

/// Regression: `!shutdown` must stop `serve_tcp` even while an idle
/// client holds an open connection. Accepted streams used to get no
/// read timeout, so the idle connection's reader thread parked in
/// `read_line` forever and the accept loop's `thread::scope` could
/// never join — the server hung on shutdown. With the timeout, idle
/// readers poll the shutdown flag and the loop returns promptly.
#[test]
fn tcp_shutdown_returns_with_idle_connection_open() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    let (booster, valid) = train("binary:logistic", 1, 2, 51, 200);
    let path = tmp("tcp_idle");
    xgb_tpu::gbm::save_model_file(&booster, &path).unwrap();
    let registry = Arc::new(ModelRegistry::open(&path).unwrap());
    let server = Arc::new(Server::start(registry, ServeOptions::default(), None));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let srv = server.clone();
    // deliberately NOT a scoped thread: if the accept loop regresses
    // into the old hang, recv_timeout below fails the test instead of
    // the test itself hanging on scope join
    let accept_loop = std::thread::spawn(move || {
        let r = srv.serve_tcp(listener);
        let _ = done_tx.send(());
        r
    });

    // idle client: connects and never sends a byte
    let idle = TcpStream::connect(addr).unwrap();

    // active client: one scored row, then a server-wide shutdown
    let cols = valid.x.n_cols();
    let row_line: String = (0..cols)
        .map(|c| format!("{}", valid.x.get(0, c).unwrap_or(Float::NAN)))
        .collect::<Vec<_>>()
        .join(",");
    let want = booster.predict(&valid.x)[0];
    let active = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(active.try_clone().unwrap());
    writeln!(&active, "{row_line}").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert_line_matches(resp.trim_end(), &[want], "tcp row");
    writeln!(&active, "!shutdown").unwrap();

    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("serve_tcp still blocked after !shutdown with an idle connection open");
    accept_loop.join().unwrap().unwrap();
    drop(idle);
    drop(active);
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    std::fs::remove_file(&path).ok();
}

/// Stream-order bookkeeping around control verbs and bad lines: `!stats`
/// and parse errors answer in position (flush barrier), empty lines are
/// skipped, `!quit` ends the stream without shutting the server down.
#[test]
fn controls_errors_and_quit_answer_in_stream_order() {
    let (booster, valid) = train("binary:logistic", 1, 2, 41, 400);
    let path = tmp("controls");
    xgb_tpu::gbm::save_model_file(&booster, &path).unwrap();
    let registry = Arc::new(ModelRegistry::open(&path).unwrap());
    let server = Server::start(registry, ServeOptions::default(), None);
    let cols = valid.x.n_cols();
    let row_line: String = (0..cols)
        .map(|c| format!("{}", valid.x.get(0, c).unwrap_or(Float::NAN)))
        .collect::<Vec<_>>()
        .join(",");
    let want = booster.predict(&valid.x)[0];

    let input = format!(
        "{row_line}\n\n!stats\nnot,a,number\n{row_line}\n!quit\n{row_line}\n"
    );
    let (lines, summary) = run_stream(&server, &input);
    assert_eq!(lines.len(), 4, "row, stats, error, row — nothing after !quit");
    assert_line_matches(&lines[0], &[want], "first row");
    assert!(lines[1].starts_with("!ok {"), "stats JSON in position: {}", lines[1]);
    assert!(lines[1].contains("\"requests\":"), "{}", lines[1]);
    assert!(lines[2].starts_with("!err "), "parse error in position: {}", lines[2]);
    assert_line_matches(&lines[3], &[want], "second row");
    assert_eq!(summary.served, 2);
    assert_eq!(summary.errors, 0, "parse errors never reach the scorer");
    assert!(!summary.shutdown, "!quit ends the stream, not the server");

    // the server is still alive: a new stream serves normally
    let (lines2, summary2) = run_stream(&server, &format!("{row_line}\n!shutdown\n"));
    assert_eq!(lines2.len(), 1);
    assert_line_matches(&lines2[0], &[want], "post-quit stream");
    assert!(summary2.shutdown, "!shutdown flags the server to stop");
    server.shutdown();
    std::fs::remove_file(&path).ok();
}
