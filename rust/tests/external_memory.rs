//! External-memory determinism suite: training off spilled page files
//! (`max_resident_pages > 0`) must produce **bit-identical** trees,
//! predictions and metrics to the fully resident path — for every page
//! size, residency budget, thread count and device count, on dense CSV
//! and sparse LibSVM data — while peak resident compressed bytes stay
//! bounded by `max_resident_pages × page_bytes` (the acceptance contract
//! of `rust/src/compress/page.rs`).

use std::path::PathBuf;

use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator};
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::{load_csv, load_libsvm, save_csv, save_libsvm, Dataset, LibsvmSource};
use xgb_tpu::gbm::{Booster, Learner, LearnerParams, ObjectiveKind};
use xgb_tpu::GradPair;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("xgb_tpu_extmem_{name}_{}", std::process::id()))
}

fn base_params(objective: ObjectiveKind, threads: usize, devices: usize) -> LearnerParams {
    LearnerParams {
        objective,
        num_rounds: 4,
        max_depth: 3,
        max_bins: 16,
        n_devices: devices,
        threads,
        compress: true,
        eval_every: 1,
        ..Default::default()
    }
}

/// Trees, base score and the whole eval history compared at the bit
/// level — the same contract the streaming-ingest suite pins.
fn assert_identical(reference: &Booster, paged: &Booster, ctx: &str) {
    assert_eq!(reference.trees, paged.trees, "{ctx}: trees differ");
    assert_eq!(reference.base_score, paged.base_score, "{ctx}: base score");
    assert_eq!(
        reference.eval_history.len(),
        paged.eval_history.len(),
        "{ctx}: eval history length"
    );
    for (a, b) in reference.eval_history.iter().zip(paged.eval_history.iter()) {
        assert_eq!(
            a.train.to_bits(),
            b.train.to_bits(),
            "{ctx} round {}: train metric {} vs {}",
            a.round,
            a.train,
            b.train
        );
        assert_eq!(
            a.valid.map(f64::to_bits),
            b.valid.map(f64::to_bits),
            "{ctx} round {}: valid metric",
            a.round
        );
    }
}

/// Page-size sweep per shard size: one page holds everything, ~3 pages,
/// and many tiny pages (64 rows).
fn page_sizes(shard_rows: usize) -> [usize; 3] {
    [shard_rows + 1, shard_rows.div_ceil(3).max(1), 64]
}

#[test]
fn dense_csv_paged_training_is_bit_identical() {
    let g = generate(&DatasetSpec::airline_like(700), 41);
    let path = tmp("dense.csv");
    save_csv(&g.train, &path).unwrap();
    // both runs read the same text round-trip so they see identical floats
    let mem = load_csv(&path, 0, false).unwrap();

    for devices in [1usize, 3] {
        for threads in [1usize, 4] {
            let params = base_params(ObjectiveKind::BinaryLogistic, threads, devices);
            let reference = Learner::from_params(params.clone())
                .unwrap()
                .train(&mem, Some(&g.valid))
                .unwrap();
            assert_eq!(reference.build_stats.pages_loaded, 0, "resident run spills nothing");
            let shard_rows = mem.n_rows().div_ceil(devices);
            for page_rows in page_sizes(shard_rows) {
                for budget in [1usize, 3] {
                    let mut p = params.clone();
                    p.max_resident_pages = budget;
                    p.page_rows = page_rows;
                    let paged = Learner::from_params(p)
                        .unwrap()
                        .train(&mem, Some(&g.valid))
                        .unwrap();
                    let ctx = format!(
                        "dense devices={devices} threads={threads} \
                         page_rows={page_rows} budget={budget}"
                    );
                    assert_identical(&reference, &paged, &ctx);
                    assert_eq!(
                        reference.predict(&g.valid.x),
                        paged.predict(&g.valid.x),
                        "{ctx}: predictions"
                    );
                    assert!(
                        paged.build_stats.pages_loaded > 0,
                        "{ctx}: paged run must actually hit the spill file"
                    );
                    assert!(
                        paged.build_stats.peak_resident_page_bytes > 0,
                        "{ctx}: peak resident bytes must be measured"
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sparse_libsvm_paged_streaming_is_bit_identical() {
    // sparse CSR + qid groups through the full out-of-core stack: stream
    // ingestion (two-pass) packing straight into the spill writer
    let g = generate(&DatasetSpec::ranking_like(600), 43);
    let path = tmp("sparse.libsvm");
    save_libsvm(&g.train, &path).unwrap();
    let mem = load_libsvm(&path).unwrap();

    for devices in [1usize, 3] {
        for threads in [1usize, 4] {
            let params = base_params(ObjectiveKind::RankPairwise, threads, devices);
            let reference = Learner::from_params(params.clone())
                .unwrap()
                .train(&mem, None)
                .unwrap();
            let shard_rows = mem.n_rows().div_ceil(devices);
            for page_rows in page_sizes(shard_rows) {
                for budget in [1usize, 3] {
                    let mut p = params.clone();
                    p.max_resident_pages = budget;
                    p.page_rows = page_rows;
                    p.batch_rows = 97; // streamed batches ⊥ page boundaries
                    let mut src = LibsvmSource::open(&path, p.batch_rows).unwrap();
                    let paged = Learner::from_params(p)
                        .unwrap()
                        .train_from_source(&mut src, None)
                        .unwrap();
                    let ctx = format!(
                        "sparse devices={devices} threads={threads} \
                         page_rows={page_rows} budget={budget}"
                    );
                    assert_identical(&reference, &paged, &ctx);
                    assert!(paged.build_stats.pages_loaded > 0, "{ctx}: no pages loaded");
                }
            }
        }
    }
    let _ = std::fs::remove_file(&path);
}

fn logistic_grads(ds: &Dataset) -> Vec<GradPair> {
    ds.y
        .iter()
        .map(|&y| GradPair::new(0.5 - y, 0.25))
        .collect()
}

#[test]
fn peak_resident_bytes_bounded_by_budget() {
    let g = generate(&DatasetSpec::higgs_like(4_000), 7);
    for (threads, budget) in [(1usize, 1usize), (1, 3), (4, 1), (4, 2), (4, 5)] {
        let params = CoordinatorParams {
            n_devices: 2,
            compress: true,
            max_bins: 16,
            max_resident_pages: budget,
            page_rows: 128,
            threads,
            ..Default::default()
        };
        let mut c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params).unwrap();
        let grads = logistic_grads(&g.train);
        let r = c.build_tree(&grads).unwrap();
        // the bound: budget × the largest page of any shard
        let max_page_bytes = c
            .devices
            .iter()
            .map(|d| match &d.storage {
                xgb_tpu::coordinator::device::ShardStorage::Paged(ps) => ps.max_page_bytes(),
                _ => panic!("expected paged storage"),
            })
            .max()
            .unwrap();
        assert!(r.stats.pages_loaded > 0, "budget={budget}");
        assert!(
            r.stats.peak_resident_page_bytes <= budget * max_page_bytes,
            "threads={threads} budget={budget}: peak {} > {} ({} x {})",
            r.stats.peak_resident_page_bytes,
            budget * max_page_bytes,
            budget,
            max_page_bytes
        );
        // spilled far exceeds the resident budget on this shape
        let spilled: usize = c.device_bytes().iter().sum();
        assert!(
            spilled > budget * max_page_bytes,
            "fixture too small to exercise paging: spilled {spilled}"
        );
        // after the tree, only the repartition cursors may hold a page
        for d in &c.devices {
            assert!(d.storage.resident_bytes() <= max_page_bytes);
        }
    }
}

#[test]
fn paged_and_resident_share_spill_invariant_cuts() {
    // paging must not perturb quantisation: cuts come from pass 1, pages
    // from pass 2 — identical cuts either way
    let g = generate(&DatasetSpec::higgs_like(900), 11);
    let resident = MultiDeviceCoordinator::from_dmatrix(
        &g.train.x,
        CoordinatorParams {
            n_devices: 2,
            compress: true,
            max_bins: 16,
            ..Default::default()
        },
    )
    .unwrap();
    let paged = MultiDeviceCoordinator::from_dmatrix(
        &g.train.x,
        CoordinatorParams {
            n_devices: 2,
            compress: true,
            max_bins: 16,
            max_resident_pages: 2,
            page_rows: 100,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(resident.cuts, paged.cuts);
    // decoded shard content matches the resident packed shards exactly
    for (r, p) in resident.devices.iter().zip(paged.devices.iter()) {
        let xgb_tpu::coordinator::device::ShardStorage::Compressed(cm) = &r.storage else {
            panic!("resident shard should be compressed");
        };
        let xgb_tpu::coordinator::device::ShardStorage::Paged(ps) = &p.storage else {
            panic!("paged shard should be paged");
        };
        let mut decoded: Vec<u32> = Vec::new();
        for page in 0..ps.n_pages() {
            decoded.extend(ps.load_page(page).unwrap().matrix.decode().bins);
        }
        assert_eq!(decoded, cm.decode().bins, "shard {}", r.id);
    }
}

#[test]
fn paging_rejects_uncompressed_storage() {
    let g = generate(&DatasetSpec::higgs_like(300), 13);
    let err = MultiDeviceCoordinator::from_dmatrix(
        &g.train.x,
        CoordinatorParams {
            compress: false,
            max_resident_pages: 2,
            ..Default::default()
        },
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("compress"), "{err:#}");
    // and the typed params surface reports it at validation time
    let p = LearnerParams {
        compress: false,
        max_resident_pages: 2,
        ..Default::default()
    };
    assert!(p.validate().is_err());
}
