//! Cross-module integration tests: end-to-end training behaviour on every
//! Table 1 task family, multi-device determinism, compression parity, and
//! failure injection (DESIGN.md §6) — all through the typed [`Learner`]
//! API.

use xgb_tpu::baselines::{train_catboost_like, train_lightgbm_like, CatBoostParams, LightGbmParams};
use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator, NativeBackend};
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::Dataset;
use xgb_tpu::gbm::{
    AllReduce, Booster, Learner, LearnerParams, MetricKind, ObjectiveKind,
};

fn quick(objective: ObjectiveKind, rounds: usize) -> LearnerParams {
    LearnerParams {
        objective,
        num_rounds: rounds,
        max_bins: 32,
        max_depth: 4,
        ..Default::default()
    }
}

fn fit(params: LearnerParams, train: &Dataset, valid: Option<&Dataset>) -> anyhow::Result<Booster> {
    let mut learner = Learner::from_params(params)?;
    learner.train(train, valid)
}

/// Every Table 1 family trains and improves over its trivial baseline.
#[test]
fn all_dataset_families_learn() {
    for (spec, better_than_trivial) in [
        (DatasetSpec::year_prediction_like(2500), true),
        (DatasetSpec::synthetic_like(2500), true),
        (DatasetSpec::higgs_like(2500), true),
        (DatasetSpec::covtype_like(2500), true),
        (DatasetSpec::bosch_like(1500), false), // heavily imbalanced: check runs, not acc
        (DatasetSpec::airline_like(2500), true),
    ] {
        let g = generate(&spec, 123);
        let mut p = quick(spec.task.objective().parse().expect("infallible"), 10);
        p.num_class = spec.task.num_class();
        p.eval_metric = Some(spec.task.metric().parse().expect("infallible"));
        let b = fit(p, &g.train, Some(&g.valid))
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let h = &b.eval_history;
        assert!(!h.is_empty(), "{}", spec.name);
        if better_than_trivial {
            let (first, last) = (h.first().unwrap(), h.last().unwrap());
            let improving = if spec.task.metric() == "rmse" {
                last.train <= first.train
            } else {
                last.train >= first.train
            };
            assert!(improving, "{}: train metric should improve", spec.name);
        }
    }
}

/// Compression is lossless end-to-end: for any device count, packed and
/// unpacked shards build identical ensembles (§2.2). Across device counts
/// the *quantisation* differs slightly (the distributed sketch merges in
/// p-dependent order, as in real distributed XGBoost), so cross-p
/// equivalence is checked at the prediction-quality level; exact cross-p
/// tree equality under shared cuts is covered by the coordinator unit
/// test `multi_device_equals_single_device`.
#[test]
fn device_count_and_compression_invariance() {
    let g = generate(&DatasetSpec::airline_like(4000), 9);
    let make = |devices: usize, compress: bool| {
        let params = LearnerParams {
            n_devices: devices,
            compress,
            eval_metric: Some(MetricKind::Accuracy),
            eval_every: 0,
            ..quick(ObjectiveKind::BinaryLogistic, 5)
        };
        fit(params, &g.train, Some(&g.valid)).unwrap()
    };
    // exact parity: packed vs unpacked at fixed p
    for p in [1usize, 3, 8] {
        let a = make(p, false);
        let b = make(p, true);
        assert_eq!(a.trees[0], b.trees[0], "p={p}: compression must be lossless");
    }
    // statistical parity: accuracy stable across device counts
    let accs: Vec<f64> = [1usize, 3, 8]
        .iter()
        .map(|&p| make(p, true).eval_history.last().unwrap().valid.unwrap())
        .collect();
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 2.0, "accuracy spread across p too wide: {accs:?}");
}

/// Ring and serial all-reduce give identical models.
#[test]
fn allreduce_algo_invariance() {
    let g = generate(&DatasetSpec::higgs_like(3000), 31);
    let make = |algo: AllReduce| {
        let params = LearnerParams {
            allreduce: algo,
            n_devices: 4,
            eval_every: 0,
            ..quick(ObjectiveKind::BinaryLogistic, 4)
        };
        fit(params, &g.train, None).unwrap()
    };
    let a = make(AllReduce::Ring);
    let b = make(AllReduce::Serial);
    assert_eq!(a.trees[0], b.trees[0]);
}

/// Sparse (CSR) input trains correctly through the whole stack.
#[test]
fn sparse_end_to_end() {
    let g = generate(&DatasetSpec::bosch_like(2000), 77);
    let p = LearnerParams {
        eval_metric: Some(MetricKind::Auc),
        ..quick(ObjectiveKind::BinaryLogistic, 8)
    };
    let b = fit(p, &g.train, Some(&g.valid)).unwrap();
    let auc = b.eval_history.last().unwrap().valid.unwrap();
    assert!(auc > 0.5, "auc {auc} must beat random on sparse data");
}

/// The three trainers (xgb, lightgbm-like, catboost-like) rank roughly as
/// the paper's accuracy columns do on a binary task: xgb ≈ lgb > cat.
#[test]
fn accuracy_ordering_matches_table2_shape() {
    let g = generate(&DatasetSpec::higgs_like(6000), 55);
    let xgb = fit(
        LearnerParams {
            eta: 0.1,
            ..quick(ObjectiveKind::BinaryLogistic, 25)
        },
        &g.train,
        None,
    )
    .unwrap();
    let (lgb, _) = train_lightgbm_like(
        &LightGbmParams {
            num_rounds: 25,
            max_bins: 32,
            ..Default::default()
        },
        &g.train,
    )
    .unwrap();
    let (cat, _) = train_catboost_like(
        &CatBoostParams {
            num_rounds: 25,
            depth: 4,
            max_bins: 32,
            ..Default::default()
        },
        &g.train,
    )
    .unwrap();
    let acc = |b: &Booster| b.evaluate(&g.valid, "accuracy").unwrap();
    let (xa, la, ca) = (acc(&xgb), acc(&lgb), acc(&cat));
    eprintln!("accuracies: xgb={xa:.2} lgb={la:.2} cat={ca:.2}");
    // loose shape bound: at this tiny scale/round budget the orderings are
    // noisy; the paper-scale ordering is checked by `cargo bench table2`
    assert!(xa >= ca - 2.5, "xgb {xa} should not trail cat {ca} badly");
    assert!(la >= ca - 2.5, "lgb {la} should not trail cat {ca} badly");
    assert!(xa > 60.0 && la > 60.0 && ca > 60.0, "all must beat chance");
}

/// Failure injection: invalid configurations surface as errors — now
/// *before* training starts for everything the validator can see.
#[test]
fn invalid_configs_error_cleanly() {
    let g = generate(&DatasetSpec::higgs_like(200), 1);
    // unknown objective: rejected at build with the valid-name list
    let err = Learner::from_params(quick("no:such".parse().expect("infallible"), 1))
        .err()
        .expect("unknown objective must not validate");
    assert!(err.to_string().contains("reg:squarederror"), "{err}");
    // multiclass without num_class
    assert!(Learner::from_params(quick(ObjectiveKind::MultiSoftmax, 1)).is_err());
    // bad grow policy / allreduce strings die in the string-typed surface
    assert!(Learner::builder().set("grow_policy", "sideways").build().is_err());
    assert!(Learner::builder()
        .set("allreduce", "carrier-pigeon")
        .build()
        .is_err());
    // ... and through the deprecated legacy shim too
    #[allow(deprecated)]
    {
        let p = xgb_tpu::gbm::BoosterParams {
            grow_policy: "sideways".into(),
            ..Default::default()
        };
        assert!(xgb_tpu::gbm::Booster::train(&p, &g.train, None).is_err());
    }
    // more devices than rows is only detectable at train time
    let p = LearnerParams {
        n_devices: 1000,
        ..quick(ObjectiveKind::BinaryLogistic, 1)
    };
    let tiny = generate(&DatasetSpec::higgs_like(100), 1);
    // 100 rows -> 80 train rows < 1000 devices
    assert!(fit(p, &tiny.train, None).is_err());
}

/// Coordinator handles degenerate gradients (all-zero => no splits, tree
/// stays a stump) without dividing by zero.
#[test]
fn degenerate_gradients_yield_stump() {
    let g = generate(&DatasetSpec::higgs_like(500), 3);
    let mut c = MultiDeviceCoordinator::with_backend(
        &g.train.x,
        CoordinatorParams::default(),
        Box::new(NativeBackend::default()),
    )
    .unwrap();
    let grads = vec![xgb_tpu::GradPair::new(0.0, 1e-16); g.train.n_rows()];
    let r = c.build_tree(&grads).unwrap();
    assert_eq!(r.tree.n_leaves(), 1, "no gain anywhere -> root stays leaf");
}

/// Training continues deterministically across repeated runs.
#[test]
fn training_is_deterministic() {
    let g = generate(&DatasetSpec::synthetic_like(2000), 13);
    let p = quick(ObjectiveKind::SquaredError, 6);
    let a = fit(p.clone(), &g.train, None).unwrap();
    let b = fit(p, &g.train, None).unwrap();
    assert_eq!(a.trees[0], b.trees[0]);
    let pa = a.predict(&g.valid.x);
    let pb = b.predict(&g.valid.x);
    assert_eq!(pa, pb);
}
