//! Cross-module integration tests: end-to-end training behaviour on every
//! Table 1 task family, multi-device determinism, compression parity, and
//! failure injection (DESIGN.md §6).

use xgb_tpu::baselines::{train_catboost_like, train_lightgbm_like, CatBoostParams, LightGbmParams};
use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator, NativeBackend};
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Booster, BoosterParams};

fn quick(objective: &str, rounds: usize) -> BoosterParams {
    BoosterParams {
        objective: objective.into(),
        num_rounds: rounds,
        max_bins: 32,
        max_depth: 4,
        ..Default::default()
    }
}

/// Every Table 1 family trains and improves over its trivial baseline.
#[test]
fn all_dataset_families_learn() {
    for (spec, better_than_trivial) in [
        (DatasetSpec::year_prediction_like(2500), true),
        (DatasetSpec::synthetic_like(2500), true),
        (DatasetSpec::higgs_like(2500), true),
        (DatasetSpec::covtype_like(2500), true),
        (DatasetSpec::bosch_like(1500), false), // heavily imbalanced: check runs, not acc
        (DatasetSpec::airline_like(2500), true),
    ] {
        let g = generate(&spec, 123);
        let mut p = quick(spec.task.objective(), 10);
        p.num_class = spec.task.num_class();
        p.eval_metric = spec.task.metric().into();
        let b = Booster::train(&p, &g.train, Some(&g.valid))
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let h = &b.eval_history;
        assert!(!h.is_empty(), "{}", spec.name);
        if better_than_trivial {
            let (first, last) = (h.first().unwrap(), h.last().unwrap());
            let improving = if spec.task.metric() == "rmse" {
                last.train <= first.train
            } else {
                last.train >= first.train
            };
            assert!(improving, "{}: train metric should improve", spec.name);
        }
    }
}

/// Compression is lossless end-to-end: for any device count, packed and
/// unpacked shards build identical ensembles (§2.2). Across device counts
/// the *quantisation* differs slightly (the distributed sketch merges in
/// p-dependent order, as in real distributed XGBoost), so cross-p
/// equivalence is checked at the prediction-quality level; exact cross-p
/// tree equality under shared cuts is covered by the coordinator unit
/// test `multi_device_equals_single_device`.
#[test]
fn device_count_and_compression_invariance() {
    let g = generate(&DatasetSpec::airline_like(4000), 9);
    let make = |devices: usize, compress: bool| {
        let params = BoosterParams {
            n_devices: devices,
            compress,
            eval_metric: "accuracy".into(),
            eval_every: 0,
            ..quick("binary:logistic", 5)
        };
        Booster::train(&params, &g.train, Some(&g.valid)).unwrap()
    };
    // exact parity: packed vs unpacked at fixed p
    for p in [1usize, 3, 8] {
        let a = make(p, false);
        let b = make(p, true);
        assert_eq!(a.trees[0], b.trees[0], "p={p}: compression must be lossless");
    }
    // statistical parity: accuracy stable across device counts
    let accs: Vec<f64> = [1usize, 3, 8]
        .iter()
        .map(|&p| make(p, true).eval_history.last().unwrap().valid.unwrap())
        .collect();
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 2.0, "accuracy spread across p too wide: {accs:?}");
}

/// Ring and serial all-reduce give identical models.
#[test]
fn allreduce_algo_invariance() {
    let g = generate(&DatasetSpec::higgs_like(3000), 31);
    let make = |algo: &str| {
        let params = BoosterParams {
            allreduce: algo.into(),
            n_devices: 4,
            eval_every: 0,
            ..quick("binary:logistic", 4)
        };
        Booster::train(&params, &g.train, None).unwrap()
    };
    let a = make("ring");
    let b = make("serial");
    assert_eq!(a.trees[0], b.trees[0]);
}

/// Sparse (CSR) input trains correctly through the whole stack.
#[test]
fn sparse_end_to_end() {
    let g = generate(&DatasetSpec::bosch_like(2000), 77);
    let p = BoosterParams {
        eval_metric: "auc".into(),
        ..quick("binary:logistic", 8)
    };
    let b = Booster::train(&p, &g.train, Some(&g.valid)).unwrap();
    let auc = b.eval_history.last().unwrap().valid.unwrap();
    assert!(auc > 0.5, "auc {auc} must beat random on sparse data");
}

/// The three trainers (xgb, lightgbm-like, catboost-like) rank roughly as
/// the paper's accuracy columns do on a binary task: xgb ≈ lgb > cat.
#[test]
fn accuracy_ordering_matches_table2_shape() {
    let g = generate(&DatasetSpec::higgs_like(6000), 55);
    let xgb = Booster::train(
        &BoosterParams {
            eta: 0.1,
            ..quick("binary:logistic", 25)
        },
        &g.train,
        None,
    )
    .unwrap();
    let (lgb, _) = train_lightgbm_like(
        &LightGbmParams {
            num_rounds: 25,
            max_bins: 32,
            ..Default::default()
        },
        &g.train,
    )
    .unwrap();
    let (cat, _) = train_catboost_like(
        &CatBoostParams {
            num_rounds: 25,
            depth: 4,
            max_bins: 32,
            ..Default::default()
        },
        &g.train,
    )
    .unwrap();
    let acc = |b: &Booster| b.evaluate(&g.valid, "accuracy").unwrap();
    let (xa, la, ca) = (acc(&xgb), acc(&lgb), acc(&cat));
    eprintln!("accuracies: xgb={xa:.2} lgb={la:.2} cat={ca:.2}");
    // loose shape bound: at this tiny scale/round budget the orderings are
    // noisy; the paper-scale ordering is checked by `cargo bench table2`
    assert!(xa >= ca - 2.5, "xgb {xa} should not trail cat {ca} badly");
    assert!(la >= ca - 2.5, "lgb {la} should not trail cat {ca} badly");
    assert!(xa > 60.0 && la > 60.0 && ca > 60.0, "all must beat chance");
}

/// Failure injection: invalid configurations surface as errors, not
/// panics or silent misbehaviour.
#[test]
fn invalid_configs_error_cleanly() {
    let g = generate(&DatasetSpec::higgs_like(200), 1);
    // unknown objective
    assert!(Booster::train(&quick("no:such", 1), &g.train, None).is_err());
    // multiclass without num_class
    assert!(Booster::train(&quick("multi:softmax", 1), &g.train, None).is_err());
    // more devices than rows
    let p = BoosterParams {
        n_devices: 1000,
        ..quick("binary:logistic", 1)
    };
    let tiny = generate(&DatasetSpec::higgs_like(100), 1);
    // 100 rows -> 80 train rows < 1000 devices
    assert!(Booster::train(&p, &tiny.train, None).is_err());
    // bad grow policy / allreduce strings
    let p = BoosterParams {
        grow_policy: "sideways".into(),
        ..quick("binary:logistic", 1)
    };
    assert!(Booster::train(&p, &g.train, None).is_err());
    let p = BoosterParams {
        allreduce: "carrier-pigeon".into(),
        ..quick("binary:logistic", 1)
    };
    assert!(Booster::train(&p, &g.train, None).is_err());
}

/// Coordinator handles degenerate gradients (all-zero => no splits, tree
/// stays a stump) without dividing by zero.
#[test]
fn degenerate_gradients_yield_stump() {
    let g = generate(&DatasetSpec::higgs_like(500), 3);
    let mut c = MultiDeviceCoordinator::with_backend(
        &g.train.x,
        CoordinatorParams::default(),
        Box::new(NativeBackend),
    )
    .unwrap();
    let grads = vec![xgb_tpu::GradPair::new(0.0, 1e-16); g.train.n_rows()];
    let r = c.build_tree(&grads).unwrap();
    assert_eq!(r.tree.n_leaves(), 1, "no gain anywhere -> root stays leaf");
}

/// Training continues deterministically across repeated runs.
#[test]
fn training_is_deterministic() {
    let g = generate(&DatasetSpec::synthetic_like(2000), 13);
    let p = quick("reg:squarederror", 6);
    let a = Booster::train(&p, &g.train, None).unwrap();
    let b = Booster::train(&p, &g.train, None).unwrap();
    assert_eq!(a.trees[0], b.trees[0]);
    let pa = a.predict(&g.valid.x);
    let pb = b.predict(&g.valid.x);
    assert_eq!(pa, pb);
}
