//! Acceptance tests for the typed `Learner` API: the builder validation
//! matrix, lossless `FromStr`/`Display` round-trips for every enum
//! (property-tested), a user-defined objective + metric registered by
//! name and taken through a full train/predict/serialize/deserialize
//! cycle, and callback-driven early stopping equivalent to the legacy
//! params-driven behaviour.

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::Dataset;
use xgb_tpu::gbm::{
    load_model, save_model, AllReduce, Callback, CallbackAction, EarlyStopping, GrowPolicy,
    Learner, LearnerParams, Metric, MetricKind, MetricRegistry, MonotoneConstraints, Objective,
    ObjectiveKind, ObjectiveRegistry, RoundContext, TimeBudget,
};
use xgb_tpu::util::prop;
use xgb_tpu::{Float, GradPair};

// ---------------------------------------------------------------------
// builder validation matrix
// ---------------------------------------------------------------------

/// Each invalid cross-field combination is rejected by `build()` with a
/// message naming the offending field(s).
#[test]
fn builder_validation_matrix() {
    struct Case {
        name: &'static str,
        params: LearnerParams,
        expect: &'static str,
    }
    let base = LearnerParams::default();
    let cases = [
        Case {
            name: "multi objective without num_class",
            params: LearnerParams {
                objective: ObjectiveKind::MultiSoftmax,
                num_class: 1,
                ..base.clone()
            },
            expect: "num_class",
        },
        Case {
            name: "num_class on a binary objective",
            params: LearnerParams {
                objective: ObjectiveKind::BinaryLogistic,
                num_class: 3,
                ..base.clone()
            },
            expect: "num_class",
        },
        Case {
            name: "lossguide without max_leaves",
            params: LearnerParams {
                grow_policy: GrowPolicy::LossGuide,
                max_leaves: 0,
                ..base.clone()
            },
            expect: "max_leaves",
        },
        Case {
            name: "depthwise without max_depth",
            params: LearnerParams {
                max_depth: 0,
                ..base.clone()
            },
            expect: "max_depth",
        },
        Case {
            name: "max_leaves of one",
            params: LearnerParams {
                max_leaves: 1,
                ..base.clone()
            },
            expect: "max_leaves",
        },
        Case {
            name: "zero rounds",
            params: LearnerParams {
                num_rounds: 0,
                ..base.clone()
            },
            expect: "num_rounds",
        },
        Case {
            name: "eta out of range",
            params: LearnerParams {
                eta: 1.5,
                ..base.clone()
            },
            expect: "eta",
        },
        Case {
            name: "too few bins",
            params: LearnerParams {
                max_bins: 1,
                ..base.clone()
            },
            expect: "max_bins",
        },
        Case {
            name: "zero devices",
            params: LearnerParams {
                n_devices: 0,
                ..base.clone()
            },
            expect: "n_devices",
        },
        Case {
            name: "subsample out of range",
            params: LearnerParams {
                subsample: 0.0,
                ..base.clone()
            },
            expect: "subsample",
        },
        Case {
            name: "colsample out of range",
            params: LearnerParams {
                colsample_bytree: 2.0,
                ..base.clone()
            },
            expect: "colsample_bytree",
        },
        Case {
            name: "negative regulariser",
            params: LearnerParams {
                lambda: -1.0,
                ..base.clone()
            },
            expect: "lambda",
        },
        Case {
            name: "early stopping without eval cadence",
            params: LearnerParams {
                early_stopping_rounds: 2,
                eval_every: 0,
                ..base.clone()
            },
            expect: "early_stopping_rounds",
        },
        Case {
            name: "unknown objective name",
            params: LearnerParams {
                objective: ObjectiveKind::Custom("not:registered".into()),
                ..base.clone()
            },
            expect: "unknown objective",
        },
        Case {
            name: "unknown metric name",
            params: LearnerParams {
                eval_metric: Some(MetricKind::Custom("not:registered".into())),
                ..base.clone()
            },
            expect: "unknown eval_metric",
        },
    ];
    for case in cases {
        let err = Learner::from_params(case.params)
            .err()
            .unwrap_or_else(|| panic!("{}: must be rejected", case.name));
        assert!(
            err.to_string().contains(case.expect),
            "{}: error {err} should mention {:?}",
            case.name,
            case.expect
        );
    }
    // and the baseline configuration is clean
    assert!(Learner::from_params(base).is_ok());
}

/// `build()` reports every problem at once, not just the first.
#[test]
fn builder_reports_all_errors_together() {
    let err = Learner::builder()
        .objective(ObjectiveKind::MultiSoftmax)
        .eta(0.0)
        .n_devices(0)
        .subsample(-0.5)
        .build()
        .unwrap_err();
    assert!(err.0.len() >= 4, "expected 4+ problems, got: {err}");
}

// ---------------------------------------------------------------------
// FromStr/Display round-trip properties
// ---------------------------------------------------------------------

/// Property: every enum value survives `Display` → `FromStr` unchanged.
#[test]
fn enum_text_round_trip_property() {
    let objectives = [
        ObjectiveKind::SquaredError,
        ObjectiveKind::BinaryLogistic,
        ObjectiveKind::MultiSoftmax,
        ObjectiveKind::MultiSoftprob,
        ObjectiveKind::RankPairwise,
    ];
    let metrics = [
        MetricKind::Rmse,
        MetricKind::Mae,
        MetricKind::LogLoss,
        MetricKind::Accuracy,
        MetricKind::Error,
        MetricKind::Auc,
        MetricKind::MError,
        MetricKind::Ndcg,
    ];
    prop::check(0xA11CE, 200, |g| {
        let o = &objectives[g.int(0, objectives.len() - 1)];
        let parsed: ObjectiveKind = o.to_string().parse().expect("infallible");
        assert_eq!(&parsed, o);

        let m = &metrics[g.int(0, metrics.len() - 1)];
        let parsed: MetricKind = m.to_string().parse().expect("infallible");
        assert_eq!(&parsed, m);

        let p = if g.bool(0.5) {
            GrowPolicy::DepthWise
        } else {
            GrowPolicy::LossGuide
        };
        assert_eq!(p.to_string().parse::<GrowPolicy>().unwrap(), p);

        let a = if g.bool(0.5) {
            AllReduce::Ring
        } else {
            AllReduce::Serial
        };
        assert_eq!(a.to_string().parse::<AllReduce>().unwrap(), a);

        // random constraint vector round-trips through its text form
        let n = g.int(0, 12);
        let signs: Vec<i8> = (0..n).map(|_| g.int(0, 2) as i8 - 1).collect();
        let mc = MonotoneConstraints::new(signs).unwrap();
        let back: MonotoneConstraints = mc.to_string().parse().unwrap();
        assert_eq!(back, mc);

        // arbitrary custom names survive the objective/metric round-trip
        let custom = format!("user:obj{}", g.int(0, 999));
        let k: ObjectiveKind = custom.parse().expect("infallible");
        assert_eq!(k.to_string(), custom);
    });
}

// ---------------------------------------------------------------------
// custom objective + metric end-to-end
// ---------------------------------------------------------------------

/// Pseudo-Huber loss — a genuinely user-defined objective (not a clone of
/// a built-in): g = r/sqrt(1+r²), h = (1+r²)^(-3/2), r = ŷ − y.
struct PseudoHuber;

impl Objective for PseudoHuber {
    fn name(&self) -> &'static str {
        "custom:pseudo-huber"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        let mean = train.y.iter().sum::<Float>() / train.y.len().max(1) as Float;
        vec![mean]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        vec![ds
            .y
            .iter()
            .zip(margins[0].iter())
            .map(|(&y, &m)| {
                let r = m - y;
                let s = (1.0 + r * r).sqrt();
                GradPair::new(r / s, (1.0 / (s * s * s)).max(1e-16))
            })
            .collect()]
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].clone()
    }

    fn default_metric(&self) -> &'static str {
        "mae"
    }
}

/// Median absolute error — a user-defined metric.
struct MedianAbsError;

impl Metric for MedianAbsError {
    fn name(&self) -> &'static str {
        "custom:medae"
    }

    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let mut errs: Vec<f64> = ds
            .y
            .iter()
            .zip(preds.iter())
            .map(|(&y, &p)| ((p - y) as f64).abs())
            .collect();
        errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        errs[errs.len() / 2]
    }
}

/// A user objective and metric, registered by name, drive a full
/// train → predict → serialize → deserialize → predict cycle without any
/// crate-internal changes.
#[test]
fn custom_objective_and_metric_full_cycle() {
    ObjectiveRegistry::register("custom:pseudo-huber", |_num_class| Ok(Box::new(PseudoHuber)))
        .unwrap();
    MetricRegistry::register("custom:medae", || Box::new(MedianAbsError)).unwrap();

    let g = generate(&DatasetSpec::year_prediction_like(2500), 71);
    let mut learner = Learner::builder()
        .objective("custom:pseudo-huber".parse().expect("infallible"))
        .eval_metric("custom:medae".parse().expect("infallible"))
        .num_rounds(12)
        .max_depth(4)
        .max_bins(32)
        .build()
        .expect("registered names must validate");
    let booster = learner.train(&g.train, Some(&g.valid)).unwrap();

    // the custom metric drove evaluation and the model actually learned
    let hist = &booster.eval_history;
    assert_eq!(hist.last().unwrap().metric, "custom:medae");
    assert!(
        hist.last().unwrap().train < hist.first().unwrap().train,
        "pseudo-huber training should reduce median abs error: {} -> {}",
        hist.first().unwrap().train,
        hist.last().unwrap().train
    );

    // serialize → deserialize round-trip: the custom objective name is
    // stored in the model file and resolved through the registry on load
    let preds_before = booster.predict(&g.valid.x);
    let mut buf = Vec::new();
    save_model(&booster, &mut buf).unwrap();
    let loaded = load_model(buf.as_slice()).unwrap();
    assert_eq!(
        loaded.params.objective,
        ObjectiveKind::Custom("custom:pseudo-huber".into())
    );
    assert_eq!(loaded.predict(&g.valid.x), preds_before);
    // registry-resolved evaluation works on the reloaded model too
    let medae = loaded.evaluate(&g.valid, "custom:medae").unwrap();
    assert!(medae.is_finite());
}

/// An unregistered custom name in a model file fails to load with the
/// valid-name list (rather than panicking or mis-resolving).
#[test]
fn unregistered_objective_in_model_file_errors() {
    let model = "xgb-tpu-model v1\nobjective = nobody:registered-this\nnum_class = 1\n\
                 eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                 tree 0 0 nodes = 1\n0 leaf 0.5 1\n";
    let err = load_model(model.as_bytes()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("valid objectives"), "{msg}");
}

// ---------------------------------------------------------------------
// callbacks
// ---------------------------------------------------------------------

/// Explicit `EarlyStopping` callback stops at exactly the same round as
/// the legacy `early_stopping_rounds` parameter.
#[test]
fn callback_early_stopping_matches_params_driven() {
    let g = generate(&DatasetSpec::higgs_like(1500), 6);
    let base = LearnerParams {
        objective: ObjectiveKind::BinaryLogistic,
        num_rounds: 200,
        max_bins: 32,
        max_depth: 4,
        eta: 1.0, // aggressive -> quick overfit -> early stop
        ..Default::default()
    };

    // params-driven (implicit callback, legacy semantics)
    let mut params_driven = base.clone();
    params_driven.early_stopping_rounds = 2;
    let b_params = Learner::from_params(params_driven)
        .unwrap()
        .train(&g.train, Some(&g.valid))
        .unwrap();

    // callback-driven
    let b_callback = Learner::from_params(base)
        .unwrap()
        .with_callback(Box::new(EarlyStopping::new(2)))
        .train(&g.train, Some(&g.valid))
        .unwrap();

    assert!(b_params.n_rounds() < 200, "must stop early");
    assert_eq!(
        b_params.n_rounds(),
        b_callback.n_rounds(),
        "explicit callback must reproduce the params-driven stopping round"
    );
    assert_eq!(b_params.trees[0], b_callback.trees[0]);
}

/// Callbacks observe every round and the train-end hook fires once.
#[test]
fn callback_lifecycle_hooks_fire() {
    struct Recorder {
        rounds: usize,
        evals: usize,
        ended: usize,
    }
    impl Callback for Recorder {
        fn on_round_end(&mut self, _ctx: &RoundContext) -> anyhow::Result<CallbackAction> {
            self.rounds += 1;
            Ok(CallbackAction::Continue)
        }
        fn on_eval(
            &mut self,
            _ctx: &RoundContext,
            _record: &xgb_tpu::gbm::EvalRecord,
        ) -> anyhow::Result<CallbackAction> {
            self.evals += 1;
            Ok(CallbackAction::Continue)
        }
        fn on_train_end(&mut self, history: &[xgb_tpu::gbm::EvalRecord]) -> anyhow::Result<()> {
            self.ended += 1;
            assert_eq!(history.len(), self.evals);
            Ok(())
        }
    }
    // observe through a shared cell: the learner owns the callback box
    use std::sync::{Arc, Mutex};
    struct Shared(Arc<Mutex<Recorder>>);
    impl Callback for Shared {
        fn on_round_end(&mut self, ctx: &RoundContext) -> anyhow::Result<CallbackAction> {
            self.0.lock().unwrap().on_round_end(ctx)
        }
        fn on_eval(
            &mut self,
            ctx: &RoundContext,
            record: &xgb_tpu::gbm::EvalRecord,
        ) -> anyhow::Result<CallbackAction> {
            self.0.lock().unwrap().on_eval(ctx, record)
        }
        fn on_train_end(&mut self, history: &[xgb_tpu::gbm::EvalRecord]) -> anyhow::Result<()> {
            self.0.lock().unwrap().on_train_end(history)
        }
    }

    let recorder = Arc::new(Mutex::new(Recorder {
        rounds: 0,
        evals: 0,
        ended: 0,
    }));
    let g = generate(&DatasetSpec::higgs_like(800), 15);
    let mut learner = Learner::builder()
        .objective(ObjectiveKind::BinaryLogistic)
        .num_rounds(6)
        .max_bins(16)
        .max_depth(3)
        .eval_every(2)
        .callback(Box::new(Shared(recorder.clone())))
        .build()
        .unwrap();
    learner.train(&g.train, Some(&g.valid)).unwrap();

    let r = recorder.lock().unwrap();
    assert_eq!(r.rounds, 6);
    assert_eq!(r.evals, 3, "eval_every=2 over 6 rounds -> 3 evals");
    assert_eq!(r.ended, 1);
}

/// A zero time budget stops after the first round but still yields a
/// usable model.
#[test]
fn time_budget_caps_training() {
    let g = generate(&DatasetSpec::higgs_like(800), 23);
    let mut learner = Learner::builder()
        .objective(ObjectiveKind::BinaryLogistic)
        .num_rounds(100)
        .max_bins(16)
        .max_depth(3)
        .callback(Box::new(TimeBudget::new(0.0)))
        .build()
        .unwrap();
    let b = learner.train(&g.train, None).unwrap();
    assert_eq!(b.n_rounds(), 1);
    assert_eq!(b.predict(&g.valid.x).len(), g.valid.n_rows());
}
