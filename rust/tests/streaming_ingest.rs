//! Streaming-ingestion equivalence suite: `Learner::train_from_source`
//! must produce **bit-identical** trees, predictions and metrics to the
//! in-memory `Learner::train` path, for every batch size and thread
//! count, on dense CSV streams, sparse LibSVM streams (with qid groups)
//! and the synthetic sources — the acceptance contract of the out-of-core
//! pipeline (`rust/src/data/source.rs`).

use std::path::PathBuf;

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::{
    load_csv, load_libsvm, save_csv, save_libsvm, BatchSource, CsvSource, DMatrixSource,
    Dataset, LibsvmSource, SyntheticSource,
};
use xgb_tpu::gbm::{Booster, Learner, LearnerParams, MetricKind, ObjectiveKind};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("xgb_tpu_streaming_{name}"))
}

fn base_params(objective: ObjectiveKind, threads: usize) -> LearnerParams {
    LearnerParams {
        objective,
        num_rounds: 5,
        max_depth: 3,
        max_bins: 16,
        n_devices: 2,
        threads,
        eval_every: 1,
        ..Default::default()
    }
}

fn train_mem(params: LearnerParams, train: &Dataset, valid: Option<&Dataset>) -> Booster {
    Learner::from_params(params)
        .unwrap()
        .train(train, valid)
        .unwrap()
}

fn train_stream(
    params: LearnerParams,
    src: &mut dyn BatchSource,
    valid: Option<&Dataset>,
) -> Booster {
    Learner::from_params(params)
        .unwrap()
        .train_from_source(src, valid)
        .unwrap()
}

/// Trees, base score and the full eval history (train and valid metric
/// values, compared at the bit level) must match.
fn assert_identical(reference: &Booster, streamed: &Booster, ctx: &str) {
    assert_eq!(reference.trees, streamed.trees, "{ctx}: trees differ");
    assert_eq!(reference.base_score, streamed.base_score, "{ctx}: base score");
    assert_eq!(
        reference.eval_history.len(),
        streamed.eval_history.len(),
        "{ctx}: eval history length"
    );
    for (a, b) in reference.eval_history.iter().zip(streamed.eval_history.iter()) {
        assert_eq!(a.metric, b.metric, "{ctx}: metric name");
        assert_eq!(
            a.train.to_bits(),
            b.train.to_bits(),
            "{ctx} round {}: train metric {} vs {}",
            a.round,
            a.train,
            b.train
        );
        assert_eq!(
            a.valid.map(f64::to_bits),
            b.valid.map(f64::to_bits),
            "{ctx} round {}: valid metric",
            a.round
        );
    }
}

/// Batch sizes from the issue contract: tiny (forces many partial
/// batches), medium, and the whole dataset in one batch.
fn batch_sizes(n: usize) -> [usize; 3] {
    [7, 64, n]
}

#[test]
fn dense_csv_stream_is_bit_identical() {
    let g = generate(&DatasetSpec::airline_like(700), 41);
    let path = tmp("dense.csv");
    save_csv(&g.train, &path).unwrap();
    // the in-memory reference reads the same file through the same text
    // round-trip, so both paths see identical floats
    let mem = load_csv(&path, 0, false).unwrap();
    assert_eq!(mem.n_rows(), g.train.n_rows());

    for threads in [1usize, 4] {
        let params = base_params(ObjectiveKind::BinaryLogistic, threads);
        let reference = train_mem(params.clone(), &mem, Some(&g.valid));
        for batch in batch_sizes(mem.n_rows()) {
            let mut src = CsvSource::open(&path, 0, false, batch).unwrap();
            let streamed = train_stream(params.clone(), &mut src, Some(&g.valid));
            assert_identical(
                &reference,
                &streamed,
                &format!("csv batch={batch} threads={threads}"),
            );
            // prediction parity on held-out rows (same trees => must hold;
            // cheap belt-and-braces through the booster surface)
            assert_eq!(
                reference.predict(&g.valid.x),
                streamed.predict(&g.valid.x),
                "csv batch={batch} threads={threads}: predictions"
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sparse_libsvm_stream_with_qid_is_bit_identical() {
    // ranking data: sparse-format file + qid groups + rank:pairwise
    let g = generate(&DatasetSpec::ranking_like(600), 43);
    let path = tmp("ranking.libsvm");
    save_libsvm(&g.train, &path).unwrap();
    let mem = load_libsvm(&path).unwrap();
    assert_eq!(mem.groups, g.train.groups, "groups survive the text round-trip");

    for threads in [1usize, 4] {
        let mut params = base_params(ObjectiveKind::RankPairwise, threads);
        params.eval_metric = Some(MetricKind::Ndcg);
        let reference = train_mem(params.clone(), &mem, Some(&g.valid));
        for batch in batch_sizes(mem.n_rows()) {
            let mut src = LibsvmSource::open(&path, batch).unwrap();
            let streamed = train_stream(params.clone(), &mut src, Some(&g.valid));
            assert_identical(
                &reference,
                &streamed,
                &format!("libsvm batch={batch} threads={threads}"),
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn truly_sparse_libsvm_stream_is_bit_identical() {
    // bosch-like CSR data exercises per-shard ELLPACK strides and the
    // 1-based column autodetect of the streaming reader
    let g = generate(&DatasetSpec::bosch_like(500), 47);
    let path = tmp("bosch.libsvm");
    save_libsvm(&g.train, &path).unwrap();
    let mem = load_libsvm(&path).unwrap();

    let params = base_params(ObjectiveKind::BinaryLogistic, 2);
    let reference = train_mem(params.clone(), &mem, None);
    for batch in [23usize, mem.n_rows()] {
        let mut src = LibsvmSource::open(&path, batch).unwrap();
        let streamed = train_stream(params.clone(), &mut src, None);
        assert_identical(&reference, &streamed, &format!("bosch batch={batch}"));
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn synthetic_source_is_bit_identical_including_multiclass() {
    // covtype + multi:softmax also exercises the chunk-parallel softmax
    // gradients through the streamed label dataset
    let g = generate(&DatasetSpec::covtype_like(700), 53);
    for threads in [1usize, 4] {
        let mut params = base_params(ObjectiveKind::MultiSoftmax, threads);
        params.num_class = 7;
        params.num_rounds = 3;
        let reference = train_mem(params.clone(), &g.train, Some(&g.valid));
        for batch in batch_sizes(g.train.n_rows()) {
            let mut src = DMatrixSource::from_dataset(&g.train, batch);
            let streamed = train_stream(params.clone(), &mut src, Some(&g.valid));
            assert_identical(
                &reference,
                &streamed,
                &format!("synthetic batch={batch} threads={threads}"),
            );
        }
    }
    // and the owned SyntheticSource adapter streams the same train split
    let params = base_params(ObjectiveKind::MultiSoftmax, 1);
    let mut p = params.clone();
    p.num_class = 7;
    p.num_rounds = 3;
    let reference = train_mem(p.clone(), &g.train, None);
    let mut src = SyntheticSource::new(&DatasetSpec::covtype_like(700), 53, 64);
    assert_eq!(src.dataset().y, g.train.y, "adapter streams the train split");
    let streamed = train_stream(p, &mut src, None);
    assert_identical(&reference, &streamed, "SyntheticSource");
}

#[test]
fn compressed_and_uncompressed_streams_agree() {
    let g = generate(&DatasetSpec::higgs_like(600), 59);
    for compress in [true, false] {
        let mut params = base_params(ObjectiveKind::BinaryLogistic, 2);
        params.compress = compress;
        let reference = train_mem(params.clone(), &g.train, None);
        let mut src = DMatrixSource::from_dataset(&g.train, 37);
        let streamed = train_stream(params, &mut src, None);
        assert_identical(&reference, &streamed, &format!("compress={compress}"));
    }
}

#[test]
fn streaming_peak_transient_is_bounded_by_batch_not_dataset() {
    use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator};

    let g = generate(&DatasetSpec::higgs_like(8000), 61);
    let full_float_bytes = g.train.x.float_bytes();
    let params = CoordinatorParams {
        n_devices: 2,
        max_bins: 16,
        ..Default::default()
    };
    let mut peaks = Vec::new();
    for batch in [64usize, 512] {
        let mut src = DMatrixSource::from_dataset(&g.train, batch);
        let (_, meta) = MultiDeviceCoordinator::from_source(&mut src, params.clone()).unwrap();
        // contract: transient floats scale with the batch, not the dataset
        assert!(
            meta.peak_transient_bytes < full_float_bytes / 4,
            "batch={batch}: peak {} vs full {}",
            meta.peak_transient_bytes,
            full_float_bytes
        );
        // float part of the peak is exactly one batch's worth
        assert!(
            meta.peak_batch_float_bytes <= batch * g.train.n_cols() * 4,
            "batch={batch}: float peak {}",
            meta.peak_batch_float_bytes
        );
        peaks.push(meta.peak_transient_bytes);
    }
    assert!(
        peaks[0] < peaks[1],
        "smaller batches must mean smaller transient peaks: {peaks:?}"
    );
}
