//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io mirror, so the workspace vendors
//! the slice of `anyhow` this codebase actually uses:
//!
//! * [`Error`] — a context-chained error value (no backtraces, no
//!   downcasting),
//! * [`Result`] — `Result<T, Error>` alias with an overridable error type,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Semantics follow the real crate where they matter here: `Display` shows
//! the outermost message, `{:#}` joins the whole chain with `": "`, and
//! `Debug` shows the outermost message followed by a `Caused by:` list.
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what allows the blanket
//! `From<E: Error + Send + Sync + 'static>` conversion powering `?`.

use std::fmt;

/// Context-chained error value. Outermost context first.
pub struct Error {
    /// `chain[0]` is the outermost message, `chain.last()` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, outermost first
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_fail() -> Result<i32> {
        let n: i32 = "nope".parse().context("parsing the count")?;
        Ok(n)
    }

    #[test]
    fn context_chains_and_formats() {
        let e = parse_fail().unwrap_err();
        assert_eq!(format!("{e}"), "parsing the count");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing the count: "), "{full}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_build_messages() {
        let x = 7;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 7 bad");
        let e = anyhow!("value {} bad", 8);
        assert_eq!(e.to_string(), "value 8 bad");
        let s = String::from("owned message");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "owned message");

        fn guard(n: i32) -> Result<i32> {
            ensure!(n > 0, "n must be positive, got {n}");
            if n > 100 {
                bail!("n too big");
            }
            Ok(n)
        }
        assert!(guard(5).is_ok());
        assert!(guard(-1).unwrap_err().to_string().contains("positive"));
        assert!(guard(101).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
    }
}
